//! Properties of the content-addressed artifact plane (`artifact`):
//! the SHA-256 core matches the FIPS 180-4 vectors and streams
//! identically to one-shot hashing, corrupted pushes (blob or
//! manifest) are rejected without an engine swap, a valid push is
//! verified, canaried, and installed live with no lost queries and
//! results bit-identical to a direct load, rollback restores the
//! prior generation bit-identically, and `stamp` is idempotent.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use ds_softmax::artifact::hash;
use ds_softmax::artifact::{
    sha256_hex, stamp, HashingReader, ManifestV2, Rollout, RolloutPolicy, Sha256,
};
use ds_softmax::artifacts::write_artifact_dir;
use ds_softmax::coordinator::{Coordinator, CoordinatorConfig, NativeBatchEngine, SoftmaxEngine};
use ds_softmax::model::dssoftmax::DsSoftmax;
use ds_softmax::sparse::ExpertSet;
use ds_softmax::util::rng::Rng;

fn mk_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dss-artprops-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a small artifact (same shape every time, contents per seed)
/// into `dir`.  All generations in a test share N=40 d=8 K=4, so
/// shape compat always passes and rejections are attributable to
/// hashing alone.
fn mk_artifact(dir: &Path, seed: u64) -> ExpertSet {
    let mut rng = Rng::new(seed);
    let set = ExpertSet::synthetic(40, 8, 4, 2.0, &mut rng);
    write_artifact_dir(dir, "artprops", &set, &[0.25; 4]).unwrap();
    set
}

fn fast_policy() -> RolloutPolicy {
    RolloutPolicy {
        poll: Duration::from_millis(5),
        canary: 8,
        canary_k: 5,
        seed: 1,
        keep: 4,
    }
}

/// Spin until `cond` holds; the coordinator keeps serving probe
/// queries meanwhile so a swap always lands under live traffic.
/// Returns (submitted, ok) for the lost-query assertion.
fn drive_until(
    c: &Arc<Coordinator>,
    d: usize,
    mut cond: impl FnMut() -> bool,
    what: &str,
) -> (u64, u64) {
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut rng = Rng::new(0xD21_7E);
    let (mut submitted, mut ok) = (0u64, 0u64);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        let h = rng.normal_vec(d, 1.0);
        submitted += 1;
        if c.query(h, 5).is_ok() {
            ok += 1;
        }
    }
    (submitted, ok)
}

// ---------------------------------------------------------------- hash

/// FIPS 180-4 test vectors, including the one-million-'a' vector that
/// exercises many compression blocks and the length counter.
#[test]
fn sha256_matches_fips_vectors() {
    assert_eq!(
        sha256_hex(b""),
        "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
    );
    assert_eq!(
        sha256_hex(b"abc"),
        "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
    );
    assert_eq!(
        sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
        "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
    );
    // 1,000,000 × 'a', fed through the incremental interface in
    // deliberately awkward chunk sizes
    let mut h = Sha256::new();
    let chunk = [b'a'; 997];
    let mut fed = 0usize;
    while fed < 1_000_000 {
        let n = chunk.len().min(1_000_000 - fed);
        h.update(&chunk[..n]);
        fed += n;
    }
    assert_eq!(
        hash::hex(&h.finalize()),
        "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
    );
}

/// The streaming reader produces the same digest as one-shot hashing
/// regardless of how the consumer chops its reads — the property that
/// makes verify-while-load safe to trust.
#[test]
fn streaming_reader_matches_one_shot_for_any_chunking() {
    let data: Vec<u8> = (0..100_003u32).map(|i| (i.wrapping_mul(2654435761) >> 13) as u8).collect();
    let want = sha256_hex(&data);
    for chunk in [1usize, 7, 63, 64, 65, 4096, 100_003] {
        let mut r = HashingReader::new(&data[..]);
        let mut buf = vec![0u8; chunk];
        let mut out = Vec::new();
        loop {
            use std::io::Read;
            let n = r.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            out.extend_from_slice(&buf[..n]);
        }
        assert_eq!(out, data, "reader altered the bytes (chunk {chunk})");
        assert_eq!(hash::hex(&r.digest()), want, "digest diverged at chunk {chunk}");
    }
}

// ------------------------------------------------------------- manifest

/// `stamp` is byte-idempotent and the generation ordinal sticks
/// across re-stamps — repacking a published artifact is a no-op.
#[test]
fn pack_is_idempotent() {
    let dir = mk_dir("idem");
    mk_artifact(&dir, 11);
    stamp(&dir, Some(3)).unwrap();
    let first = std::fs::read(dir.join("manifest.json")).unwrap();
    let m2 = stamp(&dir, None).unwrap();
    assert_eq!(m2.generation, 3, "re-stamp must keep the generation");
    let second = std::fs::read(dir.join("manifest.json")).unwrap();
    assert_eq!(first, second, "re-stamp must be byte-identical");
    let _ = std::fs::remove_dir_all(&dir);
}

// -------------------------------------------------------------- rollout

/// The e2e rollout property: a v2-stamped generation dropped into the
/// watch directory is verified, canaried, and installed as a live
/// swap — the epoch advances, no query is lost across the swap, the
/// generation gauge follows, and the served results are bit-identical
/// to a coordinator built directly from the same verified artifact.
#[test]
fn watch_verify_swap_e2e() {
    let serve_dir = mk_dir("e2e-serve");
    let watch = mk_dir("e2e-watch");

    let set1 = mk_artifact(&serve_dir, 21);
    let m1 = stamp(&serve_dir, Some(1)).unwrap();
    let engine: Arc<dyn SoftmaxEngine> =
        Arc::new(NativeBatchEngine::new(DsSoftmax::new(set1.clone())));
    let c = Arc::new(Coordinator::start(engine, CoordinatorConfig::default()));
    let ro = Rollout::spawn(
        c.clone(),
        watch.clone(),
        set1,
        m1.generation,
        m1.raw_sha256.clone(),
        None,
        fast_policy(),
    );

    // push generation 2 (atomically enough for the test: the watcher
    // retries a half-written manifest on the next tick)
    let gen2 = watch.join("push-gen2");
    std::fs::create_dir_all(&gen2).unwrap();
    mk_artifact(&gen2, 22);
    stamp(&gen2, Some(2)).unwrap();

    let (submitted, ok) = drive_until(&c, 8, || c.engine_epoch() >= 1, "rollout swap");
    assert_eq!(ok, submitted, "queries lost across the rollout swap");
    assert_eq!(c.engine_epoch(), 1, "exactly one swap expected");
    assert_eq!(c.metrics.snapshot().artifact_generation, 2, "generation gauge did not follow");

    // served results must be bit-identical to a coordinator built
    // directly from the verified artifact — the watcher's load path
    // adds verification, never transformation
    let direct_set = ManifestV2::load(&gen2).unwrap().load_verified_set().unwrap();
    let reference = Arc::new(Coordinator::start(
        Arc::new(NativeBatchEngine::new(DsSoftmax::new(direct_set))),
        CoordinatorConfig::default(),
    ));
    let mut rng = Rng::new(77);
    for _ in 0..16 {
        let h = rng.normal_vec(8, 1.0);
        let got = c.query(h.clone(), 5).expect("post-swap query");
        let want = reference.query(h, 5).expect("reference query");
        assert_eq!(got, want, "rolled-out engine diverged from a direct load");
    }
    reference.shutdown();

    let swaps = ro.stop();
    assert_eq!(swaps, 1);
    c.shutdown();
    let snap = c.metrics.snapshot();
    assert_eq!(snap.completed, snap.submitted, "queries lost at shutdown");
    let _ = std::fs::remove_dir_all(&serve_dir);
    let _ = std::fs::remove_dir_all(&watch);
}

/// A single flipped bit — in a weight blob or in the manifest itself —
/// must reject the push without touching the serving engine, and the
/// watcher must stay live: a subsequent valid push still installs.
#[test]
fn corrupt_push_is_rejected_without_swap() {
    let serve_dir = mk_dir("corrupt-serve");
    let watch = mk_dir("corrupt-watch");

    let set1 = mk_artifact(&serve_dir, 31);
    let m1 = stamp(&serve_dir, Some(1)).unwrap();
    let engine: Arc<dyn SoftmaxEngine> =
        Arc::new(NativeBatchEngine::new(DsSoftmax::new(set1.clone())));
    let c = Arc::new(Coordinator::start(engine, CoordinatorConfig::default()));
    let ro = Rollout::spawn(
        c.clone(),
        watch.clone(),
        set1,
        m1.generation,
        m1.raw_sha256.clone(),
        None,
        fast_policy(),
    );

    // push A: valid manifest, one bit flipped in a weight blob
    let bad_blob = watch.join("push-badblob");
    std::fs::create_dir_all(&bad_blob).unwrap();
    mk_artifact(&bad_blob, 32);
    stamp(&bad_blob, Some(2)).unwrap();
    let blob = bad_blob.join("packed.bin");
    let mut bytes = std::fs::read(&blob).unwrap();
    bytes[17] ^= 0x01;
    std::fs::write(&blob, &bytes).unwrap();

    // push B: valid blobs, one bit flipped mid-manifest
    let bad_manifest = watch.join("push-badmanifest");
    std::fs::create_dir_all(&bad_manifest).unwrap();
    mk_artifact(&bad_manifest, 33);
    stamp(&bad_manifest, Some(3)).unwrap();
    let mpath = bad_manifest.join("manifest.json");
    let mut mbytes = std::fs::read(&mpath).unwrap();
    let mid = mbytes.len() / 2;
    mbytes[mid] ^= 0x01;
    std::fs::write(&mpath, &mbytes).unwrap();

    // give the watcher many poll periods to examine (and reject) both
    std::thread::sleep(Duration::from_millis(150));
    assert_eq!(c.engine_epoch(), 0, "a corrupted push must never swap");
    assert_eq!(c.metrics.snapshot().artifact_generation, 1, "gauge moved on a rejected push");

    // the watcher is not wedged: a valid push into the same watch dir
    // still verifies and installs
    let good = watch.join("push-good");
    std::fs::create_dir_all(&good).unwrap();
    mk_artifact(&good, 34);
    stamp(&good, Some(4)).unwrap();
    let (submitted, ok) = drive_until(&c, 8, || c.engine_epoch() >= 1, "post-rejection rollout");
    assert_eq!(ok, submitted, "queries lost across the rollout swap");
    assert_eq!(c.metrics.snapshot().artifact_generation, 4);

    let swaps = ro.stop();
    assert_eq!(swaps, 1, "only the valid push may install");
    c.shutdown();
    let _ = std::fs::remove_dir_all(&serve_dir);
    let _ = std::fs::remove_dir_all(&watch);
}

/// `dss rollback` semantics: after a rollout, dropping `rollback.json`
/// into the watch dir re-installs the previous generation — epoch
/// advances again, the gauge returns, and served results are
/// bit-identical to the pre-rollout engine.
#[test]
fn rollback_restores_prior_generation_bit_identically() {
    let serve_dir = mk_dir("rb-serve");
    let watch = mk_dir("rb-watch");

    let set1 = mk_artifact(&serve_dir, 41);
    let m1 = stamp(&serve_dir, Some(1)).unwrap();
    let engine: Arc<dyn SoftmaxEngine> =
        Arc::new(NativeBatchEngine::new(DsSoftmax::new(set1.clone())));
    let c = Arc::new(Coordinator::start(engine, CoordinatorConfig::default()));

    // record the generation-1 answers before anything swaps
    let mut rng = Rng::new(99);
    let probes: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(8, 1.0)).collect();
    let before: Vec<_> = probes
        .iter()
        .map(|h| c.query(h.clone(), 5).expect("gen-1 query"))
        .collect();

    let ro = Rollout::spawn(
        c.clone(),
        watch.clone(),
        set1,
        m1.generation,
        m1.raw_sha256.clone(),
        None,
        fast_policy(),
    );

    let gen2 = watch.join("push-gen2");
    std::fs::create_dir_all(&gen2).unwrap();
    mk_artifact(&gen2, 42);
    stamp(&gen2, Some(2)).unwrap();
    drive_until(&c, 8, || c.engine_epoch() >= 1, "rollout swap");
    assert_eq!(c.metrics.snapshot().artifact_generation, 2);

    // explicit rollback request, exactly what `dss rollback` writes
    std::fs::write(watch.join("rollback.json"), "{}\n").unwrap();
    let (submitted, ok) = drive_until(&c, 8, || c.engine_epoch() >= 2, "rollback swap");
    assert_eq!(ok, submitted, "queries lost across the rollback");
    assert_eq!(c.metrics.snapshot().artifact_generation, 1, "gauge did not return to gen 1");

    let after: Vec<_> = probes
        .iter()
        .map(|h| c.query(h.clone(), 5).expect("post-rollback query"))
        .collect();
    assert_eq!(before, after, "rollback did not restore generation 1 bit-identically");

    let swaps = ro.stop();
    assert_eq!(swaps, 1, "rollback must not count as a rollout swap");
    c.shutdown();
    let _ = std::fs::remove_dir_all(&serve_dir);
    let _ = std::fs::remove_dir_all(&watch);
}
