//! Dynamic batcher: per-expert pending queues with a size-or-deadline
//! flush policy (the serving-system half of the paper's speedup — the
//! packed expert matmul amortizes across a batch only if the router can
//! accumulate same-expert queries without hurting tail latency).
//!
//! Per-expert queues are also what keeps sharded dispatch simple: a
//! flushed batch shares one expert, and the engine's `ShardPlan` maps
//! each expert to exactly one shard, so every flush is shard-local
//! without a second routing layer.
//!
//! The queues are keyed by *expert*, not by shard, which is what lets
//! them survive a live engine swap untouched: `Coordinator::swap_engine`
//! pins the expert count across generations, so a re-plan that moves
//! experts between shards only changes where a flush executes, never
//! which queue it waits in.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::router::RoutedQuery;

/// Flush policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// flush as soon as a queue reaches this many queries
    pub max_batch: usize,
    /// flush any queue whose oldest entry has waited this long
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::from_micros(200) }
    }
}

/// Per-expert pending queues.
pub struct Batcher {
    queues: Vec<VecDeque<RoutedQuery>>,
    policy: BatchPolicy,
    pub pending: usize,
}

impl Batcher {
    pub fn new(k: usize, policy: BatchPolicy) -> Self {
        Self {
            queues: (0..k).map(|_| VecDeque::new()).collect(),
            policy,
            pending: 0,
        }
    }

    pub fn push(&mut self, q: RoutedQuery) {
        self.queues[q.route.expert()].push_back(q);
        self.pending += 1;
    }

    /// Collect every batch that is ready under the policy.  `now` is
    /// injected for testability.
    pub fn ready(&mut self, now: Instant) -> Vec<(usize, Vec<RoutedQuery>)> {
        let mut out = Vec::new();
        for (e, q) in self.queues.iter_mut().enumerate() {
            while !q.is_empty() {
                let full = q.len() >= self.policy.max_batch;
                let expired = q
                    .front()
                    .map(|r| now.duration_since(r.submitted) >= self.policy.max_wait)
                    .unwrap_or(false);
                if !(full || expired) {
                    break;
                }
                let take = q.len().min(self.policy.max_batch);
                let batch: Vec<RoutedQuery> = q.drain(..take).collect();
                self.pending -= batch.len();
                out.push((e, batch));
            }
        }
        out
    }

    /// Flush everything (shutdown path).
    pub fn drain_all(&mut self) -> Vec<(usize, Vec<RoutedQuery>)> {
        let mut out = Vec::new();
        for (e, q) in self.queues.iter_mut().enumerate() {
            while !q.is_empty() {
                let take = q.len().min(self.policy.max_batch);
                let batch: Vec<RoutedQuery> = q.drain(..take).collect();
                self.pending -= batch.len();
                out.push((e, batch));
            }
        }
        out
    }

    /// Deepest single per-expert queue — a hot-expert backlog signal
    /// (the aggregate gauge is `Metrics::queue_depth`).
    pub fn max_depth(&self) -> usize {
        self.queues.iter().map(|q| q.len()).max().unwrap_or(0)
    }

    /// Earliest deadline across queues — how long the dispatcher may
    /// sleep without violating max_wait.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .iter()
            .filter_map(|q| q.front())
            .map(|r| {
                let waited = now.duration_since(r.submitted);
                self.policy.max_wait.saturating_sub(waited)
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::Route;
    use std::sync::mpsc;

    fn q(expert: usize, submitted: Instant) -> RoutedQuery {
        let (tx, _rx) = mpsc::channel();
        RoutedQuery {
            id: 0,
            h: vec![0.0; 4],
            k: 1,
            route: Route::single(expert, 0.5),
            submitted,
            deadline: None,
            trace: 0,
            responder: tx,
        }
    }

    #[test]
    fn flushes_on_size() {
        let policy = BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(10) };
        let mut b = Batcher::new(2, policy);
        let now = Instant::now();
        for _ in 0..7 {
            b.push(q(0, now));
        }
        let ready = b.ready(now);
        // two full batches of 3, one left pending
        assert_eq!(ready.len(), 2);
        assert!(ready.iter().all(|(e, batch)| *e == 0 && batch.len() == 3));
        assert_eq!(b.pending, 1);
    }

    #[test]
    fn flushes_on_deadline() {
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) };
        let mut b = Batcher::new(2, policy);
        let past = Instant::now() - Duration::from_millis(5);
        b.push(q(1, past));
        b.push(q(1, past));
        let ready = b.ready(Instant::now());
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].0, 1);
        assert_eq!(ready[0].1.len(), 2);
        assert_eq!(b.pending, 0);
    }

    #[test]
    fn not_ready_before_deadline() {
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_secs(1) };
        let mut b = Batcher::new(1, policy);
        let now = Instant::now();
        b.push(q(0, now));
        assert!(b.ready(now).is_empty());
        assert_eq!(b.pending, 1);
    }

    #[test]
    fn keeps_experts_separate() {
        let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10) };
        let mut b = Batcher::new(3, policy);
        let now = Instant::now();
        b.push(q(0, now));
        b.push(q(1, now));
        b.push(q(0, now));
        b.push(q(1, now));
        let mut ready = b.ready(now);
        ready.sort_by_key(|(e, _)| *e);
        assert_eq!(ready.len(), 2);
        assert_eq!(ready[0].0, 0);
        assert_eq!(ready[1].0, 1);
    }

    #[test]
    fn drain_all_empties() {
        let mut b = Batcher::new(2, BatchPolicy::default());
        let now = Instant::now();
        for i in 0..5 {
            b.push(q(i % 2, now));
        }
        let all = b.drain_all();
        let total: usize = all.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, 5);
        assert_eq!(b.pending, 0);
    }

    #[test]
    fn max_depth_tracks_hot_expert() {
        let mut b = Batcher::new(3, BatchPolicy { max_batch: 100, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        assert_eq!(b.max_depth(), 0);
        b.push(q(1, now));
        b.push(q(1, now));
        b.push(q(2, now));
        assert_eq!(b.max_depth(), 2);
    }

    #[test]
    fn next_deadline_reflects_oldest() {
        let policy = BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(100) };
        let mut b = Batcher::new(1, policy);
        let now = Instant::now();
        assert!(b.next_deadline(now).is_none());
        b.push(q(0, now - Duration::from_millis(60)));
        let dl = b.next_deadline(now).unwrap();
        assert!(dl <= Duration::from_millis(41), "{dl:?}");
    }
}
