"""L1 Pallas kernel: group-lasso row norms + prune mask (Eq. 3–4).

Used on the training path each time ``L_task`` drops below the pruning
threshold: compute every class-row's ℓ2 norm in one expert, derive the
keep mask (norm > γ), and the surviving-row lasso loss contribution.

Tiled over class rows: each grid step reduces a (block_n, d) tile, so the
expert table streams HBM→VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _norms_kernel(w_ref, norms_ref, keep_ref, *, gamma: float):
    w = w_ref[...]  # (bn, d)
    sq = jnp.sum(w * w, axis=-1)
    norms = jnp.sqrt(sq)
    norms_ref[...] = norms.astype(norms_ref.dtype)
    keep_ref[...] = (norms > gamma).astype(keep_ref.dtype)


@functools.partial(jax.jit, static_argnames=("gamma", "block_n"))
def group_lasso(
    w: jax.Array, *, gamma: float, block_n: int = DEFAULT_BLOCK_N
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Row norms, keep mask and lasso loss for one (N, d) expert.

    Returns:
      (norms, keep, loss) — (N,), (N,) in {0,1}, scalar Σ norms·keep.
    """
    n, d = w.shape
    bn = min(block_n, n)
    if n % bn:
        raise ValueError(f"rows {n} not divisible by block {bn}")
    kernel = functools.partial(_norms_kernel, gamma=gamma)
    norms, keep = pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((n,), w.dtype),
        ],
        interpret=True,
    )(w)
    return norms, keep, jnp.sum(norms * keep)
