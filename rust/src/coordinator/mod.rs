//! L3 serving coordinator — the paper's system integrated as a service,
//! built on the unified `Route`/`TopKBuf` query API:
//!
//! ```text
//!   clients ──▶ ingress queue (bounded, backpressure)
//!                  │ router: sparse gate → Route (O(K·d), native)
//!                  ▼
//!          per-expert pending queues
//!                  │ dynamic batcher: flush on size or deadline
//!                  ▼
//!          worker pool ── RowPack (contiguous MatrixView of the batch)
//!                  │         │
//!                  │         ▼ SoftmaxEngine::run_expert_batch
//!                  │       pooled TopKBuf arena (no per-row Vecs)
//!                  ▼
//!          per-request response channels + metrics
//! ```
//!
//! The gate runs *before* batching so requests are grouped by expert —
//! the DS-Softmax analogue of vLLM-style continuous batching: batches
//! are only formed across requests that share the same sparse expert,
//! which is what makes the packed-expert matmul dense and fast.
//!
//! There is no separate batch-engine trait: the coordinator drives the
//! same [`SoftmaxEngine`] the model layer defines, so native, PJRT, and
//! mock backends (and any plain engine, e.g. the full-softmax baseline)
//! are interchangeable behind `Arc<dyn SoftmaxEngine>`.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

pub use engine::NativeBatchEngine;
#[cfg(feature = "pjrt")]
pub use engine::PjrtBatchEngine;
pub use metrics::Metrics;
pub use server::{Coordinator, CoordinatorConfig, QueryError};

/// The one engine trait, re-exported where the old `BatchEngine` lived.
pub use crate::model::SoftmaxEngine;
