//! The DS-Softmax inference engine (paper §2.3, inference path):
//!
//! 1. gate: `softmax(U·h)` over K experts → top-1 expert + gate value;
//! 2. expert: packed |v_k|×d logits, scaled by the gate value (inverse
//!    temperature), stable softmax;
//! 3. top-k over the packed probabilities, mapped back to global ids.
//!
//! `query_with_scratch` is the zero-allocation hot path used by the
//! coordinator workers; `query` is the convenient stateless form.

use crate::model::SoftmaxEngine;
use crate::sparse::ExpertSet;
use crate::tensor::{argmax, scaled_softmax_inplace, softmax_inplace};
use crate::util::topk::TopK;

pub struct DsSoftmax {
    pub set: ExpertSet,
    /// Expected FLOPs under the utilization profile measured at export
    /// (updated by `set_utilization`; defaults to uniform).
    utilization: Vec<f64>,
}

/// Reusable per-thread buffers for the hot path.
pub struct DsScratch {
    pub gate_logits: Vec<f32>,
    pub expert_logits: Vec<f32>,
    pub heap: TopK,
}

impl DsScratch {
    pub fn new(set: &ExpertSet, k: usize) -> Self {
        Self {
            gate_logits: vec![0.0; set.k()],
            expert_logits: vec![0.0; set.p()],
            heap: TopK::new(k),
        }
    }
}

/// Result of the gating stage — exposed so the coordinator can route
/// before running the expert stage.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GateDecision {
    pub expert: usize,
    pub gate_value: f32,
}

impl DsSoftmax {
    pub fn new(set: ExpertSet) -> Self {
        let k = set.k();
        Self { set, utilization: vec![1.0 / k as f64; k] }
    }

    pub fn with_utilization(set: ExpertSet, utilization: Vec<f64>) -> Self {
        assert_eq!(utilization.len(), set.k());
        Self { set, utilization }
    }

    pub fn set_utilization(&mut self, u: Vec<f64>) {
        assert_eq!(u.len(), self.set.k());
        self.utilization = u;
    }

    /// Stage 1: the sparse gate (Eq. 1).
    #[inline]
    pub fn gate(&self, h: &[f32], gate_logits: &mut [f32]) -> GateDecision {
        self.set.gate.matvec_into(h, gate_logits);
        softmax_inplace(gate_logits);
        let expert = argmax(gate_logits);
        GateDecision { expert, gate_value: gate_logits[expert] }
    }

    /// Stage 2: packed expert softmax + top-k (Eq. 2).
    pub fn expert_topk(
        &self,
        h: &[f32],
        decision: GateDecision,
        scratch: &mut DsScratch,
    ) -> Vec<(u32, f32)> {
        let e = &self.set.experts[decision.expert];
        let logits = &mut scratch.expert_logits[..e.valid];
        // matvec over only the valid packed rows
        for (r, out) in logits.iter_mut().enumerate() {
            *out = crate::tensor::dot(e.weights.row(r), h);
        }
        scaled_softmax_inplace(logits, decision.gate_value);
        scratch.heap.clear();
        scratch.heap.push_slice(logits);
        scratch
            .heap
            .sorted()
            .into_iter()
            .map(|(p, i)| (e.class_ids[i as usize] as u32, p))
            .collect()
    }

    /// Full hot path with caller-owned scratch (no allocation except the
    /// returned Vec).
    pub fn query_with_scratch(&self, h: &[f32], scratch: &mut DsScratch) -> Vec<(u32, f32)> {
        let d = self.gate(h, &mut scratch.gate_logits);
        self.expert_topk(h, d, scratch)
    }

    /// Routing-only entry point for the coordinator.
    pub fn route(&self, h: &[f32]) -> GateDecision {
        let mut buf = vec![0.0; self.set.k()];
        self.gate(h, &mut buf)
    }
}

impl SoftmaxEngine for DsSoftmax {
    fn query(&self, h: &[f32], k: usize) -> Vec<(u32, f32)> {
        let mut scratch = DsScratch::new(&self.set, k);
        self.query_with_scratch(h, &mut scratch)
    }

    fn flops_per_query(&self) -> u64 {
        crate::flops::ds_softmax_expected(
            &self.set.expert_sizes(),
            &self.utilization,
            self.set.dim(),
        ) as u64
    }

    fn n_classes(&self) -> usize {
        self.set.n_classes
    }

    fn dim(&self) -> usize {
        self.set.dim()
    }

    fn name(&self) -> &'static str {
        "ds-softmax"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::full::FullSoftmax;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn engine(seed: u64) -> DsSoftmax {
        let mut rng = Rng::new(seed);
        DsSoftmax::new(ExpertSet::synthetic(512, 16, 8, 1.25, &mut rng))
    }

    #[test]
    fn query_returns_k_sorted() {
        let e = engine(1);
        let mut rng = Rng::new(9);
        let h = rng.normal_vec(16, 1.0);
        let top = e.query(&h, 10);
        assert_eq!(top.len(), 10);
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        // ids are valid classes
        assert!(top.iter().all(|&(c, _)| (c as usize) < 512));
    }

    #[test]
    fn probabilities_sum_below_one() {
        // packed softmax normalizes within the expert, so top-k probs sum <= 1
        let e = engine(2);
        let mut rng = Rng::new(10);
        let h = rng.normal_vec(16, 1.0);
        let top = e.query(&h, 100);
        let sum: f32 = top.iter().map(|&(_, p)| p).sum();
        assert!(sum <= 1.0 + 1e-4);
    }

    #[test]
    fn gate_picks_argmax_expert() {
        let e = engine(3);
        let mut rng = Rng::new(11);
        let h = rng.normal_vec(16, 1.0);
        let mut buf = vec![0.0; e.set.k()];
        let d = e.gate(&h, &mut buf);
        assert_eq!(d.expert, argmax(&buf));
        assert!((0.0..=1.0).contains(&d.gate_value));
    }

    #[test]
    fn scratch_and_stateless_agree() {
        let e = engine(4);
        let mut rng = Rng::new(12);
        let mut scratch = DsScratch::new(&e.set, 5);
        for _ in 0..20 {
            let h = rng.normal_vec(16, 1.0);
            let a = e.query_with_scratch(&h, &mut scratch);
            let b = e.query(&h, 5);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn matches_full_softmax_on_expert_subset() {
        // restrict the full softmax to the chosen expert's classes with the
        // gate-scaled logits: rankings must agree exactly.
        let e = engine(5);
        let mut rng = Rng::new(13);
        let h = rng.normal_vec(16, 1.0);
        let d = e.route(&h);
        let expert = &e.set.experts[d.expert];
        // dense matrix of just the expert's rows
        let mut w = Matrix::zeros(expert.valid, 16);
        for r in 0..expert.valid {
            w.row_mut(r).copy_from_slice(expert.weights.row(r));
        }
        let full = FullSoftmax::new(w);
        let want: Vec<u32> = full
            .query(&h, 5)
            .iter()
            .map(|&(i, _)| expert.class_ids[i as usize] as u32)
            .collect();
        let got: Vec<u32> = e.query(&h, 5).iter().map(|&(c, _)| c).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn flops_less_than_full() {
        let e = engine(6);
        let full = crate::flops::full_softmax(512, 16);
        assert!(e.flops_per_query() < full);
    }

    #[test]
    fn deterministic_across_calls() {
        let e = engine(7);
        let mut rng = Rng::new(14);
        let h = rng.normal_vec(16, 1.0);
        assert_eq!(e.query(&h, 8), e.query(&h, 8));
    }
}
