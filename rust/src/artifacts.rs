//! Artifact manifests: the export contract between the Python build
//! (`python/compile/aot.py::export_ds_artifacts`) and the Rust serving
//! layer.  An artifact directory holds `manifest.json`, raw
//! little-endian weight blobs (`*.bin`, written by `numpy.tofile`), and
//! shape-specialized HLO text files keyed by logical name
//! (`gate_b8`, `expert_b32`, `lstm_step_b8`, …).
//!
//! Loading is pure Rust (the in-house JSON substrate) — no PJRT needed,
//! so the native engines can serve an exported model without the `pjrt`
//! feature.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::sparse::{ExpertSet, SparseExpert};
use crate::tensor::Matrix;
use crate::util::json::Json;

/// Default artifact root: `$DSS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_root() -> PathBuf {
    std::env::var_os("DSS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// A pluggable byte source for blob loads.  The default
/// (`Manifest::load_f32` etc.) is a plain `std::fs::read`; the
/// artifact plane substitutes a streaming-hash reader so integrity
/// checking rides along with the single pass that loads each blob.
pub type BlobReader<'a> = dyn FnMut(&Path) -> Result<Vec<u8>> + 'a;

fn plain_read(path: &Path) -> Result<Vec<u8>> {
    std::fs::read(path).with_context(|| format!("read {}", path.display()))
}

/// Export an `ExpertSet` as a v1 artifact directory (manifest.json +
/// raw little-endian blobs), the exact inverse of
/// `Manifest::expert_set`.  This is the pure-Rust counterpart of the
/// Python exporter, used by `dss gen --out` so CI and tests can mint
/// artifacts without a Python toolchain.  No HLO graphs and no
/// `w_full` are written — the packed two-level structure is the whole
/// serving contract.
pub fn write_artifact_dir(
    dir: impl AsRef<Path>,
    name: &str,
    set: &ExpertSet,
    utilization: &[f64],
) -> Result<PathBuf> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
    let (k, d, p) = (set.k(), set.dim(), set.p());
    anyhow::ensure!(
        utilization.len() == k,
        "utilization has {} entries but k={k}",
        utilization.len()
    );

    let mut packed = Vec::with_capacity(k * p * d);
    let mut class_ids = Vec::with_capacity(k * p);
    let mut valid = Vec::with_capacity(k);
    for e in &set.experts {
        packed.extend_from_slice(&e.weights.data);
        class_ids.extend_from_slice(&e.class_ids);
        valid.push(e.valid as i32);
    }
    let f32s = |xs: &[f32]| -> Vec<u8> { xs.iter().flat_map(|x| x.to_le_bytes()).collect() };
    let i32s = |xs: &[i32]| -> Vec<u8> { xs.iter().flat_map(|x| x.to_le_bytes()).collect() };
    std::fs::write(dir.join("u.bin"), f32s(&set.gate.data))?;
    std::fs::write(dir.join("packed.bin"), f32s(&packed))?;
    std::fs::write(dir.join("class_ids.bin"), i32s(&class_ids))?;
    std::fs::write(dir.join("valid.bin"), i32s(&valid))?;

    let sizes = set.expert_sizes();
    let weight = |file: &str, shape: &[usize], dtype: &str| {
        Json::obj(vec![
            ("file", file.into()),
            ("shape", Json::arr_usize(shape)),
            ("dtype", dtype.into()),
        ])
    };
    let mean_size = sizes.iter().sum::<usize>() as f64 / k as f64;
    let speedup = set.n_classes as f64 / (k as f64 + mean_size).max(1.0);
    let manifest = Json::obj(vec![
        ("name", name.into()),
        ("n_classes", set.n_classes.into()),
        ("d", d.into()),
        ("k", k.into()),
        ("p", p.into()),
        ("buckets", Json::arr_usize(&[1])),
        ("files", Json::Obj(BTreeMap::new())),
        (
            "weights",
            Json::obj(vec![
                ("u", weight("u.bin", &[k, d], "f32")),
                ("packed", weight("packed.bin", &[k, p, d], "f32")),
                ("class_ids", weight("class_ids.bin", &[k, p], "i32")),
                ("valid", weight("valid.bin", &[k], "i32")),
            ]),
        ),
        ("utilization", Json::arr_f64(utilization)),
        ("expert_sizes", Json::arr_usize(&sizes)),
        ("speedup_theoretical", speedup.into()),
    ]);
    let path = dir.join("manifest.json");
    std::fs::write(&path, format!("{manifest}\n"))
        .with_context(|| format!("write {}", path.display()))?;
    Ok(path)
}

/// One weight blob's metadata.
#[derive(Clone, Debug)]
pub struct WeightInfo {
    pub file: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl WeightInfo {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// LSTM section of an LM artifact.
#[derive(Clone, Debug)]
pub struct LstmInfo {
    pub vocab: usize,
    pub embed: usize,
    pub hidden: usize,
    pub layers: usize,
}

/// Parsed `manifest.json` plus the directory it came from.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub name: String,
    pub n_classes: usize,
    pub d: usize,
    pub k: usize,
    pub p: usize,
    pub buckets: Vec<usize>,
    /// logical HLO name → file name
    pub files: BTreeMap<String, String>,
    pub weights: BTreeMap<String, WeightInfo>,
    pub utilization: Vec<f64>,
    pub expert_sizes: Vec<usize>,
    pub speedup_theoretical: f64,
    pub lstm: Option<LstmInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parse {}", path.display()))?;

        let mut files = BTreeMap::new();
        for (k, v) in j.get("files")?.as_obj()? {
            files.insert(k.clone(), v.as_str()?.to_string());
        }
        let mut weights = BTreeMap::new();
        for (k, v) in j.get("weights")?.as_obj()? {
            weights.insert(
                k.clone(),
                WeightInfo {
                    file: v.get("file")?.as_str()?.to_string(),
                    shape: v.get("shape")?.usize_vec()?,
                    dtype: v.get("dtype")?.as_str()?.to_string(),
                },
            );
        }
        let lstm = match j.opt("lstm") {
            Some(l) => Some(LstmInfo {
                vocab: l.get("vocab")?.as_usize()?,
                embed: l.get("embed")?.as_usize()?,
                hidden: l.get("hidden")?.as_usize()?,
                layers: l.get("layers")?.as_usize()?,
            }),
            None => None,
        };
        let m = Self {
            name: j.get("name")?.as_str()?.to_string(),
            n_classes: j.get("n_classes")?.as_usize()?,
            d: j.get("d")?.as_usize()?,
            k: j.get("k")?.as_usize()?,
            p: j.get("p")?.as_usize()?,
            buckets: j.get("buckets")?.usize_vec()?,
            utilization: j.get("utilization")?.f64_vec()?,
            expert_sizes: j.get("expert_sizes")?.usize_vec()?,
            speedup_theoretical: j.get("speedup_theoretical")?.as_f64()?,
            files,
            weights,
            lstm,
            dir,
        };
        m.validate_shape()
            .with_context(|| format!("invalid manifest {}", path.display()))?;
        Ok(m)
    }

    /// Cross-field shape validation, applied at parse time so a bad
    /// manifest fails with a clear error instead of surfacing later
    /// deep inside `expert_set()`.
    fn validate_shape(&self) -> Result<()> {
        anyhow::ensure!(self.d > 0, "artifact '{}': d must be > 0", self.name);
        anyhow::ensure!(
            self.n_classes > 0,
            "artifact '{}': n_classes must be > 0",
            self.name
        );
        anyhow::ensure!(self.k > 0, "artifact '{}': k must be > 0", self.name);
        anyhow::ensure!(self.p > 0, "artifact '{}': p must be > 0", self.name);
        anyhow::ensure!(
            self.expert_sizes.len() == self.k,
            "artifact '{}': expert_sizes has {} entries but k={}",
            self.name,
            self.expert_sizes.len(),
            self.k
        );
        anyhow::ensure!(
            self.utilization.len() == self.k,
            "artifact '{}': utilization has {} entries but k={}",
            self.name,
            self.utilization.len(),
            self.k
        );
        Ok(())
    }

    /// Path of one logical HLO graph (e.g. `gate_b8`).
    pub fn hlo_path(&self, logical: &str) -> Result<PathBuf> {
        let f = self
            .files
            .get(logical)
            .ok_or_else(|| anyhow!("artifact '{}' has no graph '{logical}'", self.name))?;
        Ok(self.dir.join(f))
    }

    fn blob_with(
        &self,
        name: &str,
        read: &mut BlobReader<'_>,
    ) -> Result<(Vec<u8>, &WeightInfo)> {
        let info = self
            .weights
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{}' has no weight '{name}'", self.name))?;
        let path = self.dir.join(&info.file);
        let bytes = read(&path)?;
        anyhow::ensure!(
            bytes.len() == info.elems() * 4,
            "{name}: {} bytes but shape {:?} needs {}",
            bytes.len(),
            info.shape,
            info.elems() * 4
        );
        Ok((bytes, info))
    }

    /// Load a little-endian f32 blob by weight name.
    pub fn load_f32(&self, name: &str) -> Result<Vec<f32>> {
        self.load_f32_with(name, &mut plain_read)
    }

    /// `load_f32` with a caller-supplied byte source (the artifact
    /// plane routes this through a `HashingReader` so blobs are
    /// verified while streaming, in the one pass that loads them).
    pub fn load_f32_with(&self, name: &str, read: &mut BlobReader<'_>) -> Result<Vec<f32>> {
        let (bytes, info) = self.blob_with(name, read)?;
        anyhow::ensure!(info.dtype == "f32", "{name}: dtype {} != f32", info.dtype);
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Load a little-endian i32 blob by weight name.
    pub fn load_i32(&self, name: &str) -> Result<Vec<i32>> {
        self.load_i32_with(name, &mut plain_read)
    }

    /// `load_i32` with a caller-supplied byte source.
    pub fn load_i32_with(&self, name: &str, read: &mut BlobReader<'_>) -> Result<Vec<i32>> {
        let (bytes, info) = self.blob_with(name, read)?;
        anyhow::ensure!(info.dtype == "i32", "{name}: dtype {} != i32", info.dtype);
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// The exact full-softmax weight matrix (N×d).
    pub fn full_weights(&self) -> Result<Matrix> {
        let w = self.load_f32("w_full")?;
        Ok(Matrix::from_vec(self.n_classes, self.d, w))
    }

    /// Reassemble the packed two-level structure exported by `ds_pack`.
    pub fn expert_set(&self) -> Result<ExpertSet> {
        self.expert_set_with(&mut plain_read)
    }

    /// `expert_set` with a caller-supplied byte source; every blob is
    /// read exactly once through `read`.
    pub fn expert_set_with(&self, read: &mut BlobReader<'_>) -> Result<ExpertSet> {
        let u = self.load_f32_with("u", read)?;
        let packed = self.load_f32_with("packed", read)?;
        let class_ids = self.load_i32_with("class_ids", read)?;
        let valid = self.load_i32_with("valid", read)?;
        let (k, p, d) = (self.k, self.p, self.d);
        anyhow::ensure!(u.len() == k * d, "gate shape mismatch");
        anyhow::ensure!(packed.len() == k * p * d, "packed shape mismatch");
        anyhow::ensure!(class_ids.len() == k * p, "class_ids shape mismatch");
        anyhow::ensure!(valid.len() == k, "valid shape mismatch");
        let experts = (0..k)
            .map(|e| {
                SparseExpert::new(
                    Matrix::from_vec(p, d, packed[e * p * d..(e + 1) * p * d].to_vec()),
                    class_ids[e * p..(e + 1) * p].to_vec(),
                    valid[e] as usize,
                )
            })
            .collect();
        Ok(ExpertSet {
            gate: Matrix::from_vec(k, d, u),
            experts,
            n_classes: self.n_classes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        // tiny 2-expert set: N=4, d=2, p=2
        let manifest = r#"{
 "name": "t",
 "n_classes": 4,
 "d": 2,
 "k": 2,
 "p": 2,
 "buckets": [1, 8],
 "block_p": 2,
 "files": {"gate_b1": "gate_b1.hlo.txt"},
 "weights": {
  "u": {"file": "u.bin", "shape": [2, 2], "dtype": "f32"},
  "packed": {"file": "packed.bin", "shape": [2, 2, 2], "dtype": "f32"},
  "class_ids": {"file": "class_ids.bin", "shape": [2, 2], "dtype": "i32"},
  "valid": {"file": "valid.bin", "shape": [2], "dtype": "i32"},
  "w_full": {"file": "w_full.bin", "shape": [4, 2], "dtype": "f32"}
 },
 "utilization": [0.5, 0.5],
 "expert_sizes": [2, 2],
 "speedup_theoretical": 1.0
}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let f32s = |xs: &[f32]| -> Vec<u8> {
            xs.iter().flat_map(|x| x.to_le_bytes()).collect()
        };
        let i32s = |xs: &[i32]| -> Vec<u8> {
            xs.iter().flat_map(|x| x.to_le_bytes()).collect()
        };
        std::fs::write(dir.join("u.bin"), f32s(&[1.0, 0.0, 0.0, 1.0])).unwrap();
        std::fs::write(
            dir.join("packed.bin"),
            f32s(&[1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 0.5, 0.5]),
        )
        .unwrap();
        std::fs::write(dir.join("class_ids.bin"), i32s(&[0, 1, 2, 3])).unwrap();
        std::fs::write(dir.join("valid.bin"), i32s(&[2, 2])).unwrap();
        std::fs::write(
            dir.join("w_full.bin"),
            f32s(&[1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 0.5, 0.5]),
        )
        .unwrap();
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("dss-artifact-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!((m.n_classes, m.d, m.k, m.p), (4, 2, 2, 2));
        assert_eq!(m.buckets, vec![1, 8]);
        assert!(m.lstm.is_none());
        let set = m.expert_set().unwrap();
        set.validate().unwrap();
        assert_eq!(set.k(), 2);
        assert_eq!(set.experts[1].class_ids, vec![2, 3]);
        assert_eq!(set.experts[0].weights.row(1), &[0.0, 1.0]);
        let w = m.full_weights().unwrap();
        assert_eq!(w.rows, 4);
        assert_eq!(w.row(3), &[0.5, 0.5]);
        assert_eq!(
            m.hlo_path("gate_b1").unwrap(),
            dir.join("gate_b1.hlo.txt")
        );
        assert!(m.hlo_path("missing").is_err());
        assert!(m.load_i32("u").is_err()); // dtype guard
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_is_clean_error() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }

    /// Shape mismatches must fail at parse time with a clear error,
    /// not later inside `expert_set()`.
    #[test]
    fn load_rejects_inconsistent_shapes() {
        let dir = std::env::temp_dir().join(format!("dss-artifact-badshape-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir);
        let good = std::fs::read_to_string(dir.join("manifest.json")).unwrap();

        let cases = [
            // (broken manifest text, expected error fragment)
            (good.replace("\"expert_sizes\": [2, 2]", "\"expert_sizes\": [2]"), "expert_sizes"),
            (good.replace("\"utilization\": [0.5, 0.5]", "\"utilization\": [0.5]"), "utilization"),
            (good.replace("\"d\": 2", "\"d\": 0"), "d must be > 0"),
            (good.replace("\"n_classes\": 4", "\"n_classes\": 0"), "n_classes must be > 0"),
            (good.replace("\"p\": 2", "\"p\": 0"), "p must be > 0"),
            (good.replace("\"k\": 2", "\"k\": 0"), "k must be > 0"),
        ];
        for (text, frag) in cases {
            std::fs::write(dir.join("manifest.json"), &text).unwrap();
            let err = Manifest::load(&dir).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(frag), "expected '{frag}' in: {msg}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `write_artifact_dir` is the exact inverse of `expert_set`.
    #[test]
    fn export_roundtrip() {
        use crate::util::rng::Rng;
        let dir = std::env::temp_dir().join(format!("dss-artifact-export-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut rng = Rng::new(11);
        let set = ExpertSet::synthetic(40, 8, 4, 2.0, &mut rng);
        let util = vec![0.25; 4];
        write_artifact_dir(&dir, "roundtrip", &set, &util).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.name, "roundtrip");
        assert_eq!((m.n_classes, m.d, m.k, m.p), (40, 8, 4, set.p()));
        let back = m.expert_set().unwrap();
        back.validate().unwrap();
        assert_eq!(back.gate.data, set.gate.data);
        for (a, b) in back.experts.iter().zip(set.experts.iter()) {
            assert_eq!(a.weights.data, b.weights.data);
            assert_eq!(a.class_ids, b.class_ids);
            assert_eq!(a.valid, b.valid);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
