//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client via the `xla` crate.
//!
//! Executables are shape-specialized, so the coordinator keys them by
//! (artifact logical name) which already encodes the batch bucket (e.g.
//! `gate_b8`).  Weights can be uploaded once as device buffers and
//! reused across queries (`execute_b`), keeping the request hot path
//! free of host→device weight copies.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::artifacts::Manifest;
use crate::tensor::Matrix;

/// Wrapper over the PJRT CPU client + an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text file (cached by logical name).
    pub fn load(
        &self,
        manifest: &Manifest,
        logical: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(logical) {
            return Ok(e.clone());
        }
        let path = manifest.hlo_path(logical)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {logical}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(logical.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }

    /// Upload a literal once as a device buffer (for weights).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("to_device: {e:?}"))
    }

    /// Execute with pre-uploaded device buffers.
    pub fn run_b(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::PjRtBuffer> = inputs.to_vec();
        let out = exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }
}

/// Literal construction helpers.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn lit_scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn lit_matrix(m: &Matrix) -> Result<xla::Literal> {
    lit_f32(&m.data, &[m.rows as i64, m.cols as i64])
}

/// Extract an f32 vector from an output literal.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

pub fn to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))
}

/// LSTM weights resident as literals, fed to `lstm_step_b{B}`.
pub struct LstmWeights {
    pub hidden: usize,
    pub vocab: usize,
    pub embed: xla::Literal,
    pub wx0: xla::Literal,
    pub wh0: xla::Literal,
    pub b0: xla::Literal,
    pub wx1: xla::Literal,
    pub wh1: xla::Literal,
    pub b1: xla::Literal,
}

/// High-level engine over the AOT artifacts: gating + expert softmax +
/// full softmax executed through PJRT at the manifest's batch buckets.
pub struct PjrtDsEngine {
    pub runtime: Runtime,
    pub manifest: Manifest,
    /// expert weights resident on device: (packed rows literal per expert)
    expert_weights: Vec<xla::Literal>,
    gate_weights: xla::Literal,
    full_weights: xla::Literal,
    valid: Vec<i32>,
    class_ids: Vec<Vec<i32>>,
}

impl PjrtDsEngine {
    pub fn new(runtime: Runtime, manifest: Manifest) -> Result<Self> {
        let set = manifest.expert_set()?;
        let gate_weights = lit_matrix(&set.gate)?;
        let expert_weights = set
            .experts
            .iter()
            .map(|e| lit_matrix(&e.weights))
            .collect::<Result<Vec<_>>>()?;
        let full = manifest.full_weights()?;
        let full_weights = lit_matrix(&full)?;
        Ok(Self {
            valid: set.experts.iter().map(|e| e.valid as i32).collect(),
            class_ids: set.experts.iter().map(|e| e.class_ids.clone()).collect(),
            runtime,
            manifest,
            expert_weights,
            gate_weights,
            full_weights,
        })
    }

    /// Smallest exported bucket >= n (callers pad to this).
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.manifest
            .buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .or_else(|| self.manifest.buckets.iter().copied().max())
            .context("no buckets in manifest")
    }

    /// Gate a batch: returns (probs row-major B×K, top1 per row).
    /// `h` must have exactly `bucket` rows (pad with zeros beforehand).
    pub fn gate(&self, h: &Matrix, bucket: usize) -> Result<(Vec<f32>, Vec<i32>)> {
        anyhow::ensure!(h.rows == bucket, "h rows {} != bucket {bucket}", h.rows);
        let exe = self.runtime.load(&self.manifest, &format!("gate_b{bucket}"))?;
        let hl = lit_matrix(h)?;
        let out = self.runtime.run(&exe, &[hl, self.gate_weights.clone()])?;
        anyhow::ensure!(out.len() == 2, "gate returned {} outputs", out.len());
        Ok((to_f32(&out[0])?, to_i32(&out[1])?))
    }

    /// Packed-expert softmax for a batch routed to `expert`.
    /// Returns row-major B×P probabilities.
    pub fn expert_probs(
        &self,
        expert: usize,
        h: &Matrix,
        gate_values: &[f32],
        bucket: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(h.rows == bucket && gate_values.len() == bucket);
        let exe = self
            .runtime
            .load(&self.manifest, &format!("expert_b{bucket}"))?;
        let out = self.runtime.run(
            &exe,
            &[
                lit_matrix(h)?,
                self.expert_weights[expert].clone(),
                lit_f32(gate_values, &[bucket as i64])?,
                lit_scalar_i32(self.valid[expert]),
            ],
        )?;
        to_f32(&out[0])
    }

    /// Full-softmax baseline through PJRT.
    pub fn full_probs(&self, h: &Matrix, bucket: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(h.rows == bucket);
        let exe = self.runtime.load(&self.manifest, &format!("full_b{bucket}"))?;
        let out = self
            .runtime
            .run(&exe, &[lit_matrix(h)?, self.full_weights.clone()])?;
        to_f32(&out[0])
    }

    /// One LSTM decode step through the AOT `lstm_step_b{B}` graph.
    ///
    /// `tokens` length must equal `bucket`; `state` is the flattened
    /// (layers, 2, bucket, hidden) carry (zeros at sequence start).
    /// Returns (contexts row-major bucket×hidden, new state).
    pub fn lstm_step(
        &self,
        lstm: &LstmWeights,
        tokens: &[i32],
        state: &[f32],
        bucket: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(tokens.len() == bucket);
        let hidden = lstm.hidden;
        anyhow::ensure!(state.len() == 2 * 2 * bucket * hidden);
        let exe = self
            .runtime
            .load(&self.manifest, &format!("lstm_step_b{bucket}"))?;
        let out = self.runtime.run(
            &exe,
            &[
                lstm.embed.clone(),
                lstm.wx0.clone(),
                lstm.wh0.clone(),
                lstm.b0.clone(),
                lstm.wx1.clone(),
                lstm.wh1.clone(),
                lstm.b1.clone(),
                lit_i32(tokens, &[bucket as i64])?,
                lit_f32(state, &[2, 2, bucket as i64, hidden as i64])?,
            ],
        )?;
        anyhow::ensure!(out.len() == 2, "lstm_step returned {} outputs", out.len());
        Ok((to_f32(&out[0])?, to_f32(&out[1])?))
    }

    /// Load the LSTM weights as literals (once, at startup).
    pub fn lstm_weights(&self) -> Result<LstmWeights> {
        let info = self
            .manifest
            .lstm
            .as_ref()
            .context("artifact has no lstm section")?;
        let lm = |name: &str| -> Result<xla::Literal> {
            let w = self.manifest.load_f32(name)?;
            let shape = &self.manifest.weights[name].shape;
            let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
            lit_f32(&w, &dims)
        };
        Ok(LstmWeights {
            hidden: info.hidden,
            vocab: info.vocab,
            embed: lm("lstm_embed")?,
            wx0: lm("wx0")?,
            wh0: lm("wh0")?,
            b0: lm("b0")?,
            wx1: lm("wx1")?,
            wh1: lm("wh1")?,
            b1: lm("b1")?,
        })
    }

    /// Whole inference for a batch (gate → group → expert → top-k),
    /// returning per-row top-k (class, prob).
    pub fn query_batch(&self, h: &Matrix, k: usize) -> Result<Vec<Vec<(u32, f32)>>> {
        let n = h.rows;
        let bucket = self.bucket_for(n)?;
        let mut hp = Matrix::zeros(bucket, h.cols);
        hp.data[..n * h.cols].copy_from_slice(&h.data);
        let (probs, top1) = self.gate(&hp, bucket)?;
        let kk = self.manifest.k;
        // group rows by expert
        let mut groups: HashMap<usize, Vec<usize>> = HashMap::new();
        for (row, &e) in top1.iter().take(n).enumerate() {
            groups.entry(e as usize).or_default().push(row);
        }
        let p = self.manifest.p;
        let mut results: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n];
        for (expert, rows) in groups {
            let gb = self.bucket_for(rows.len())?;
            let mut hh = Matrix::zeros(gb, h.cols);
            let mut gv = vec![0.0f32; gb];
            for (i, &r) in rows.iter().enumerate() {
                hh.row_mut(i).copy_from_slice(h.row(r));
                gv[i] = probs[r * kk + expert];
            }
            let pp = self.expert_probs(expert, &hh, &gv, gb)?;
            for (i, &r) in rows.iter().enumerate() {
                let row_probs = &pp[i * p..(i + 1) * p];
                let top = crate::util::topk::topk(row_probs, k);
                results[r] = top
                    .into_iter()
                    .map(|(prob, idx)| (self.class_ids[expert][idx as usize] as u32, prob))
                    .collect();
            }
        }
        Ok(results)
    }
}
