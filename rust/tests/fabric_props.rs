//! Fabric-correctness properties: the distributed topology must be an
//! exact drop-in for the in-process engines.  Loopback shard-workers
//! host real `DsSoftmax` slices; a `RemoteShardEngine` scatters to
//! them over `fabric::proto`; and the results must match the
//! unsharded `DsSoftmax` AND the in-process `ShardedEngine` bit for
//! bit — across shard counts, replication factors, and the edge
//! batches (empty, single row).  Replica death mid-stream degrades to
//! retry-once-failover with zero lost or duplicated queries.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Instant;

use ds_softmax::coordinator::{Coordinator, CoordinatorConfig, NativeBatchEngine, QueryError};
use ds_softmax::fabric::{FabricClient, FabricFront, FabricOpts, RemoteShardEngine, ShardWorker};
use ds_softmax::model::dssoftmax::DsSoftmax;
use ds_softmax::model::SoftmaxEngine;
use ds_softmax::query::{MatrixView, TopKBuf};
use ds_softmax::shard::{ReplicaPlan, ShardPlan, ShardedEngine};
use ds_softmax::sparse::ExpertSet;
use ds_softmax::util::rng::Rng;

/// Spin up one loopback worker process-analogue per replica slot
/// (shard-major), returning the workers and their addresses in the
/// order `RemoteShardEngine::connect` expects.
fn spawn_cluster(set: &ExpertSet, rplan: &ReplicaPlan) -> (Vec<ShardWorker>, Vec<String>) {
    let mut workers = Vec::new();
    let mut addrs = Vec::new();
    for shard in 0..rplan.plan.shards {
        for _replica in 0..rplan.replicas[shard] {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let w = ShardWorker::spawn_for(set.clone(), &rplan.plan, shard, listener).unwrap();
            addrs.push(w.local_addr().to_string());
            workers.push(w);
        }
    }
    (workers, addrs)
}

fn assert_rows_equal(got: &TopKBuf, want: &TopKBuf, ctx: &str) {
    assert_eq!(got.rows(), want.rows(), "{ctx}: row count");
    assert_eq!(got.to_vecs(), want.to_vecs(), "{ctx}: rows diverged");
}

/// The acceptance property: remote == local sharded == unsharded,
/// bit-identical, for S ∈ {1, 2, 4} × replication ∈ {1, 2} × batch
/// sizes {0, 1, random}, including the coordinator's
/// `run_expert_batch` flush shape.
#[test]
fn remote_equals_local_sharded_equals_unsharded() {
    let mut rng = Rng::new(61);
    let set = ExpertSet::synthetic(256, 16, 6, 1.2, &mut rng);
    let reference = DsSoftmax::new(set.clone());
    let k = 5usize;
    for s in [1usize, 2, 4] {
        for repl in [1usize, 2] {
            let plan = ShardPlan::greedy(&set, s);
            let rplan = ReplicaPlan::uniform(plan.clone(), repl);
            let sharded = ShardedEngine::new(set.clone(), plan).unwrap();
            let (workers, addrs) = spawn_cluster(&set, &rplan);
            let remote =
                RemoteShardEngine::connect(&set, rplan, &addrs, FabricOpts::default()).unwrap();
            assert_eq!(remote.n_shards(), s);
            let mut want = TopKBuf::new();
            let mut local = TopKBuf::new();
            let mut got = TopKBuf::new();
            for b in [0usize, 1, 1 + rng.below(24)] {
                let packed: Vec<f32> = (0..b * 16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let hs = MatrixView::new(&packed, b, 16);
                let ctx = format!("S={s} repl={repl} b={b}");
                reference.query_batch(hs, k, &mut want);
                sharded.query_batch(hs, k, &mut local);
                remote.query_batch(hs, k, &mut got);
                assert_rows_equal(&local, &want, &format!("{ctx} (sharded)"));
                assert_rows_equal(&got, &want, &format!("{ctx} (remote)"));
                // the coordinator flush shape: one expert, shared gate
                if b > 0 {
                    let gates = vec![0.7f32; b];
                    for e in [0usize, set.k() - 1] {
                        reference.run_expert_batch(e, hs, &gates, k, &mut want).unwrap();
                        remote.run_expert_batch(e, hs, &gates, k, &mut got).unwrap();
                        assert_rows_equal(&got, &want, &format!("{ctx} expert {e}"));
                    }
                }
            }
            drop(workers); // Drop stops every worker thread
        }
    }
}

/// Kill one replica mid-stream: every query still answers, every
/// answer is still exact, and the metrics plane records the failovers
/// — zero lost, zero duplicated.
#[test]
fn replica_death_degrades_to_failover_without_loss() {
    let mut rng = Rng::new(77);
    let set = ExpertSet::synthetic(256, 16, 4, 1.2, &mut rng);
    let reference = DsSoftmax::new(set.clone());
    let plan = ShardPlan::greedy(&set, 2);
    let rplan = ReplicaPlan::uniform(plan, 2);
    let (mut workers, addrs) = spawn_cluster(&set, &rplan);
    let remote = RemoteShardEngine::connect(
        &set,
        rplan,
        &addrs,
        FabricOpts { io_timeout: std::time::Duration::from_secs(2), ..Default::default() },
    )
    .unwrap();

    let mut want = TopKBuf::new();
    let mut got = TopKBuf::new();
    for i in 0..60 {
        if i == 30 {
            // shard 0, replica 0 dies; its sibling must absorb the load
            workers[0].stop();
        }
        let h = rng.normal_vec(16, 1.0);
        let hs = MatrixView::new(&h, 1, 16);
        reference.query_batch(hs, 5, &mut want);
        remote.query_batch(hs, 5, &mut got);
        assert_rows_equal(&got, &want, &format!("query {i}"));
    }
    // force traffic onto the dead replica's shard so the failover path
    // is exercised even if routing happened to avoid shard 0 above
    let owned = remote.replica_plan().plan.experts_on(0);
    let e = owned[0];
    let h = rng.normal_vec(16, 1.0);
    let hs = MatrixView::new(&h, 1, 16);
    reference.run_expert_batch(e, hs, &[0.5], 5, &mut want).unwrap();
    remote.run_expert_batch(e, hs, &[0.5], 5, &mut got).unwrap();
    assert_rows_equal(&got, &want, "post-kill expert batch");

    let snap = remote.metrics().snapshot();
    let failovers: u64 = snap.replicas.iter().map(|r| r.failovers).sum();
    let retries: u64 = snap.replicas.iter().map(|r| r.retries).sum();
    assert!(failovers >= 1, "expected at least one failover, snapshot {snap:?}");
    assert!(retries >= 1, "expected retried queries on the sibling, snapshot {snap:?}");
    drop(workers);
}

/// The full pipeline over the wire: coordinator → RemoteShardEngine →
/// loopback workers serves exact answers, and per-query deadlines
/// surface as typed timeouts.
#[test]
fn coordinator_over_remote_engine_with_deadlines() {
    let mut rng = Rng::new(5);
    let set = ExpertSet::synthetic(192, 12, 4, 1.2, &mut rng);
    let reference = DsSoftmax::new(set.clone());
    let plan = ShardPlan::greedy(&set, 2);
    let rplan = ReplicaPlan::uniform(plan, 1);
    let (workers, addrs) = spawn_cluster(&set, &rplan);
    let remote = Arc::new(
        RemoteShardEngine::connect(&set, rplan, &addrs, FabricOpts::default()).unwrap(),
    );
    let c = Coordinator::start(remote, CoordinatorConfig { shards: 2, ..Default::default() });

    let queries: Vec<Vec<f32>> = (0..80).map(|_| rng.normal_vec(12, 1.0)).collect();
    let pend: Vec<_> = queries.iter().map(|h| c.submit(h.clone(), 4).unwrap()).collect();
    for (h, p) in queries.iter().zip(pend) {
        assert_eq!(p.wait().unwrap(), reference.query(h, 4));
    }
    // an already-expired deadline sheds with the typed timeout error
    let p = c
        .submit_with_deadline(queries[0].clone(), 4, Some(Instant::now()))
        .unwrap();
    assert_eq!(p.wait(), Err(QueryError::Timeout));
    assert!(c.metrics.snapshot().timeouts >= 1);
    c.shutdown();
    drop(workers);
}

/// The serving front end-to-end: a pipelining client gets exact
/// answers and typed wire errors, stats round-trips the metrics
/// snapshot, and a client-initiated shutdown stops the front.
#[test]
fn front_and_client_roundtrip() {
    let mut rng = Rng::new(23);
    let set = ExpertSet::synthetic(128, 10, 4, 1.2, &mut rng);
    let reference = DsSoftmax::new(set.clone());
    let engine = Arc::new(NativeBatchEngine::new(DsSoftmax::new(set)));
    let c = Arc::new(Coordinator::start(engine, CoordinatorConfig::default()));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let mut front = FabricFront::spawn(listener, c.clone(), None).unwrap();
    let mut cl = FabricClient::connect(&front.local_addr().to_string()).unwrap();

    // pipelined correctness: submit a window, then match ids
    let queries: Vec<Vec<f32>> = (0..40).map(|_| rng.normal_vec(10, 1.0)).collect();
    let ids: Vec<u64> = queries.iter().map(|h| cl.submit(h, 5).unwrap()).collect();
    let mut got = vec![None; queries.len()];
    for _ in 0..queries.len() {
        let (id, res) = cl.recv().unwrap();
        let idx = ids.iter().position(|&i| i == id).unwrap();
        assert!(got[idx].is_none(), "duplicate response for id {id}");
        got[idx] = Some(res.unwrap());
    }
    for (h, top) in queries.iter().zip(&got) {
        assert_eq!(top.as_ref().unwrap(), &reference.query(h, 5));
    }

    // a malformed query surfaces as the typed rejection, not a hangup
    let bad = cl.query(&[0.0f32; 3], 5);
    let err = bad.unwrap_err();
    match err.downcast_ref::<QueryError>() {
        Some(QueryError::Rejected(msg)) => assert!(msg.contains("dimension"), "{msg}"),
        other => panic!("expected a typed rejection, got {other:?}"),
    }

    // stats round-trips the snapshot (completed counts what we served)
    let stats = cl.stats().unwrap();
    let completed = stats.get("completed").unwrap().as_usize().unwrap();
    assert!(completed >= 40, "completed={completed}");

    // client-initiated shutdown: acknowledged, then the front stops
    cl.shutdown_server().unwrap();
    front.wait();
    c.shutdown();
}
