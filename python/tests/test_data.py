"""Data substrate generators (data.py)."""
import numpy as np

from compile import data


def test_hierarchical_clusters_shapes():
    x, y, sup = data.hierarchical_clusters(3, 5, n_per_sub=7, dim=10, seed=0)
    assert x.shape == (3 * 5 * 7, 10) and y.shape == (105,)
    assert sup.shape == (15,)
    assert set(y.tolist()) == set(range(15))
    assert (np.bincount(sup) == 5).all()


def test_hierarchical_clusters_separation():
    """Super-cluster scale dominates sub-cluster scale (Eq. 7-9)."""
    x, y, sup = data.hierarchical_clusters(5, 5, n_per_sub=20, dim=50, seed=1)
    # centroid distance between different super >> within same super
    cents = np.stack([x[y == c].mean(0) for c in range(25)])
    within, across = [], []
    for a in range(25):
        for b in range(a + 1, 25):
            d = np.linalg.norm(cents[a] - cents[b])
            (within if sup[a] == sup[b] else across).append(d)
    assert np.mean(across) > 2 * np.mean(within)


def test_zipf_corpus_skew_and_range():
    toks = data.zipf_topic_corpus(500, 20000, seed=2)
    assert toks.min() >= 0 and toks.max() < 500
    counts = np.bincount(toks, minlength=500)
    # Zipf: top-10% of words cover most of the mass
    top = np.sort(counts)[::-1]
    assert top[:50].sum() > 0.5 * counts.sum()


def test_zipf_corpus_topic_structure():
    """Consecutive tokens share a topic band far more than chance."""
    vocab, n_topics = 400, 8
    toks = data.zipf_topic_corpus(vocab, 20000, n_topics=n_topics, seed=3)
    band = vocab // n_topics
    t = toks // band
    same = (t[1:] == t[:-1]).mean()
    assert same > 0.3  # i.i.d. zipf would be much lower


def test_lm_batches_shift():
    toks = np.arange(1000, dtype=np.int32)
    xs, ys = data.lm_batches(toks, batch=4, seq=10)
    assert (ys == xs + 1).all()


def test_translation_pairs_structure():
    src, tgt = data.translation_pairs(100, vocab_src=200, vocab_tgt=300, seed=4)
    assert src.shape == tgt.shape
    assert (src[:, 0] == 1).all() and (tgt[:, 0] == 1).all()  # BOS
    assert (src == 2).sum(axis=1).min() >= 1  # EOS present
    assert src.max() < 200 and tgt.max() < 300


def test_translation_deterministic_lexicon():
    """Same source word maps to the same target word across pairs."""
    src, tgt = data.translation_pairs(300, vocab_src=50, vocab_tgt=80,
                                      swap_prob=0.0, fertility_prob=0.0, seed=5)
    mapping = {}
    for s_row, t_row in zip(src, tgt):
        s = [w for w in s_row if w >= 3]
        t = [w for w in t_row if w >= 3]
        assert len(s) == len(t)
        for a, b in zip(s, t):
            assert mapping.setdefault(a, b) == b


def test_glyphs_uniform_classes():
    x, y = data.glyphs(20, 15, seed=6)
    assert x.shape == (300, 144)
    assert (np.bincount(y) == 15).all()


def test_glyphs_classes_distinguishable():
    """Nearest-prototype classification on clean data beats chance hugely."""
    x, y = data.glyphs(10, 30, stroke_noise=0.1, seed=7)
    cents = np.stack([x[y == c].mean(0) for c in range(10)])
    pred = np.argmin(((x[:, None] - cents[None]) ** 2).sum(-1), axis=1)
    assert (pred == y).mean() > 0.9


def test_train_test_split_disjoint():
    x = np.arange(90).reshape(30, 3).astype(np.float32)
    y = np.arange(30, dtype=np.int32)
    xtr, ytr, xte, yte = data.train_test_split(x, y, frac=2 / 3, seed=8)
    assert len(xtr) == 20 and len(xte) == 10
    assert set(ytr.tolist()) | set(yte.tolist()) == set(range(30))
    assert not (set(ytr.tolist()) & set(yte.tolist()))
