//! Content-addressed artifact store: the durable home of every
//! verified generation.
//!
//! Layout, rooted at `<watch-dir>/.store/`:
//!
//! ```text
//! .store/
//!   objects/<sha256-hex>              raw blobs, named by content
//!   manifests/gen-<g>-<hash8>/
//!     manifest.json                   v2, weight files -> ../../objects/<hex>
//! ```
//!
//! Blobs are named by their own digest, so two generations that share
//! a weight share the bytes on disk, and any number of generations
//! coexist — rollback is "load the previous manifest dir", not
//! "restore a backup".  Objects are written via temp-file + rename so
//! a crashed ingest never leaves a half-written blob under a final
//! name.  Store manifests are re-stamped after the file fields are
//! rewritten, so everything in the store passes the same
//! `ManifestV2::load` + streaming verification as a fresh push.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::artifact::hash;
use crate::artifact::manifest::{stamp, ManifestV2};
use crate::util::json::Json;

pub struct Store {
    root: PathBuf,
}

impl Store {
    /// Open (creating if needed) the store under a watch directory.
    pub fn open(watch: impl AsRef<Path>) -> Result<Self> {
        let root = watch.as_ref().join(".store");
        std::fs::create_dir_all(root.join("objects"))
            .with_context(|| format!("create {}", root.join("objects").display()))?;
        std::fs::create_dir_all(root.join("manifests"))
            .with_context(|| format!("create {}", root.join("manifests").display()))?;
        Ok(Self { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Ingest a verified manifest: copy every blob into `objects/`
    /// (deduplicated by content), write a store manifest whose file
    /// fields point at the objects, and re-stamp it.  Idempotent: a
    /// generation already ingested under the same pushed-manifest
    /// identity is returned as-is.
    pub fn ingest(&self, m2: &ManifestV2) -> Result<ManifestV2> {
        let dir = self.root.join("manifests").join(format!(
            "gen-{}-{}",
            m2.generation,
            &m2.raw_sha256[..8]
        ));
        if dir.join("manifest.json").is_file() {
            return ManifestV2::load(&dir);
        }
        std::fs::create_dir_all(&dir).with_context(|| format!("create {}", dir.display()))?;

        // Re-read the pushed manifest and rewrite blob references.
        let src_path = m2.base.dir.join("manifest.json");
        let text = std::fs::read_to_string(&src_path)
            .with_context(|| format!("read {}", src_path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parse {}", src_path.display()))?;
        let mut m = j.as_obj().map_err(anyhow::Error::from)?.clone();

        let mut weights = std::collections::BTreeMap::new();
        for (name, w) in m
            .get("weights")
            .ok_or_else(|| anyhow::anyhow!("no weights table"))?
            .as_obj()?
            .clone()
        {
            let mut wo = w.as_obj()?.clone();
            let file = wo
                .get("file")
                .ok_or_else(|| anyhow::anyhow!("weight '{name}' has no file"))?
                .as_str()?
                .to_string();
            let expect = m2
                .blob_sha
                .get(&name)
                .ok_or_else(|| anyhow::anyhow!("no recorded sha256 for weight '{name}'"))?;
            let bytes = hash::read_verified(&m2.base.dir.join(&file), expect)?;
            self.put_object(expect, &bytes)?;
            wo.insert(
                "file".to_string(),
                Json::Str(format!("../../objects/{expect}")),
            );
            weights.insert(name, Json::Obj(wo));
        }
        m.insert("weights".to_string(), Json::Obj(weights));

        // HLO texts ride along the same way.
        let mut files = std::collections::BTreeMap::new();
        if let Some(fs) = m.get("files") {
            for (logical, file) in fs.as_obj()?.clone() {
                let expect = m2
                    .file_sha
                    .get(&logical)
                    .ok_or_else(|| anyhow::anyhow!("no recorded sha256 for file '{logical}'"))?;
                let bytes = hash::read_verified(&m2.base.dir.join(file.as_str()?), expect)?;
                self.put_object(expect, &bytes)?;
                files.insert(logical, Json::Str(format!("../../objects/{expect}")));
            }
        }
        m.insert("files".to_string(), Json::Obj(files));
        // Stale against the rewritten file fields; stamp() recomputes.
        m.remove("files_sha256");
        m.remove("self_sha256");

        std::fs::write(dir.join("manifest.json"), format!("{}\n", Json::Obj(m)))
            .with_context(|| format!("write {}", dir.join("manifest.json").display()))?;
        stamp(&dir, Some(m2.generation))
    }

    /// Write one object by digest, atomically, skipping if present.
    fn put_object(&self, sha_hex: &str, bytes: &[u8]) -> Result<()> {
        let path = self.root.join("objects").join(sha_hex);
        if path.is_file() {
            return Ok(());
        }
        let tmp = self
            .root
            .join("objects")
            .join(format!(".tmp-{}-{sha_hex}", std::process::id()));
        std::fs::write(&tmp, bytes).with_context(|| format!("write {}", tmp.display()))?;
        std::fs::rename(&tmp, &path).with_context(|| format!("rename to {}", path.display()))?;
        Ok(())
    }

    /// All stored generations, ascending, with their manifest dirs.
    pub fn generations(&self) -> Result<Vec<(u64, PathBuf)>> {
        let mut out = Vec::new();
        let dir = self.root.join("manifests");
        for entry in
            std::fs::read_dir(&dir).with_context(|| format!("read {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(rest) = name.strip_prefix("gen-") {
                if let Some((g, _hash8)) = rest.split_once('-') {
                    if let Ok(g) = g.parse::<u64>() {
                        out.push((g, entry.path()));
                    }
                }
            }
        }
        out.sort();
        Ok(out)
    }

    /// Manifest dir of one stored generation, if present.
    pub fn manifest_dir(&self, generation: u64) -> Result<Option<PathBuf>> {
        Ok(self
            .generations()?
            .into_iter()
            .find(|(g, _)| *g == generation)
            .map(|(_, p)| p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts::write_artifact_dir;
    use crate::sparse::ExpertSet;
    use crate::util::rng::Rng;

    #[test]
    fn ingest_dedups_and_generations_coexist() {
        let base = std::env::temp_dir().join(format!("dss-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let push1 = base.join("push1");
        let push2 = base.join("push2");
        let mut rng = Rng::new(21);
        let set1 = ExpertSet::synthetic(40, 8, 4, 2.0, &mut rng);
        let set2 = ExpertSet::synthetic(40, 8, 4, 2.0, &mut rng);
        write_artifact_dir(&push1, "g1", &set1, &[0.25; 4]).unwrap();
        write_artifact_dir(&push2, "g2", &set2, &[0.25; 4]).unwrap();
        let m1 = stamp(&push1, Some(1)).unwrap();
        let m2 = stamp(&push2, Some(2)).unwrap();

        let store = Store::open(base.join("watch")).unwrap();
        let s1 = store.ingest(&m1).unwrap();
        let s2 = store.ingest(&m2).unwrap();
        assert_eq!(s1.generation, 1);
        assert_eq!(s2.generation, 2);
        // Both generations verifiable and loadable from the store.
        assert_eq!(s1.verify_blobs().unwrap(), 4);
        assert_eq!(
            s1.load_verified_set().unwrap().gate.data,
            set1.gate.data
        );
        assert_eq!(
            s2.load_verified_set().unwrap().gate.data,
            set2.gate.data
        );
        let gens = store.generations().unwrap();
        assert_eq!(gens.iter().map(|(g, _)| *g).collect::<Vec<_>>(), vec![1, 2]);
        assert!(store.manifest_dir(2).unwrap().is_some());
        assert!(store.manifest_dir(9).unwrap().is_none());
        // Idempotent re-ingest.
        let s1b = store.ingest(&m1).unwrap();
        assert_eq!(s1b.self_sha256, s1.self_sha256);
        let _ = std::fs::remove_dir_all(&base);
    }
}
