//! Manifest v2: the integrity-checked superset of `manifest.json`.
//!
//! A v2 manifest is a strict superset of the v1 format `artifacts.rs`
//! parses — every v1 field survives untouched, so `Manifest::load`
//! still works on a stamped directory — plus:
//!
//! - `manifest_version: 2` — format gate;
//! - `generation: N` — monotone rollout ordinal; the watcher only
//!   installs strictly newer generations;
//! - per-weight `sha256` and a `files_sha256` map for the HLO texts —
//!   every byte the loader will touch has a recorded digest;
//! - `compat: {d, n_classes, k}` — the shape contract a running
//!   engine checks *before* reading any blob;
//! - `self_sha256` — digest of the manifest's own canonical rendering
//!   with `self_sha256` set to `""`.  The in-house `Json` renders
//!   objects in `BTreeMap` order with no insignificant whitespace, so
//!   stamping and verification canonicalize identically and a single
//!   flipped bit anywhere in the file either breaks the parse or
//!   breaks this digest.
//!
//! `stamp` (behind `dss pack`) upgrades a directory in place and is
//! idempotent: re-stamping an already-stamped directory rewrites the
//! byte-identical file.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::artifact::hash;
use crate::artifacts::Manifest;
use crate::sparse::ExpertSet;
use crate::util::json::Json;

/// The shape-compatibility block checked against a serving engine
/// before any blob is read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Compat {
    pub d: usize,
    pub n_classes: usize,
    pub k: usize,
}

/// A parsed, self-hash-verified v2 manifest.
#[derive(Clone, Debug)]
pub struct ManifestV2 {
    /// The v1 view (blob metadata, shapes, loader methods).
    pub base: Manifest,
    pub generation: u64,
    pub compat: Compat,
    /// weight name → expected blob sha256 (hex).
    pub blob_sha: BTreeMap<String, String>,
    /// logical HLO name → expected file sha256 (hex).
    pub file_sha: BTreeMap<String, String>,
    pub self_sha256: String,
    /// sha256 of the manifest file's raw on-disk bytes — the identity
    /// the rollout watcher keys seen/rejected candidates on.
    pub raw_sha256: String,
}

/// Canonical self-hash of a parsed manifest object: render with
/// `self_sha256` forced to `""`, digest the rendering.
fn self_hash(j: &Json) -> Result<String> {
    let mut m = j.as_obj().map_err(anyhow::Error::from)?.clone();
    m.insert("self_sha256".to_string(), Json::Str(String::new()));
    Ok(hash::sha256_hex(Json::Obj(m).to_string().as_bytes()))
}

impl ManifestV2 {
    /// Load and structurally verify a v2 manifest: version gate,
    /// self-hash, v1 shape validation.  Blob hashes are *not* checked
    /// here — that happens while streaming in `load_verified_set` /
    /// `verify_blobs`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let raw = std::fs::read(&path).with_context(|| format!("read {}", path.display()))?;
        let raw_sha256 = hash::sha256_hex(&raw);
        let text = std::str::from_utf8(&raw)
            .with_context(|| format!("{} is not UTF-8", path.display()))?;
        let j = Json::parse(text).with_context(|| format!("parse {}", path.display()))?;

        let version = match j.opt("manifest_version") {
            Some(v) => v.as_usize().map_err(anyhow::Error::from)?,
            None => 1,
        };
        anyhow::ensure!(
            version == 2,
            "{}: manifest_version {} (need 2 — run `dss pack` to stamp)",
            path.display(),
            version
        );
        let self_sha256 = j
            .get("self_sha256")
            .map_err(anyhow::Error::from)?
            .as_str()
            .map_err(anyhow::Error::from)?
            .to_string();
        let computed = self_hash(&j)?;
        anyhow::ensure!(
            computed == self_sha256,
            "{}: self_sha256 mismatch: manifest claims {}, canonical rendering hashes to {} \
             (manifest tampered or hand-edited after stamping)",
            path.display(),
            self_sha256,
            computed
        );

        let base = Manifest::load(&dir)?;
        let generation = j.get("generation").map_err(anyhow::Error::from)?.as_f64()? as u64;
        let c = j.get("compat").map_err(anyhow::Error::from)?;
        let compat = Compat {
            d: c.get("d")?.as_usize()?,
            n_classes: c.get("n_classes")?.as_usize()?,
            k: c.get("k")?.as_usize()?,
        };
        anyhow::ensure!(
            compat.d == base.d && compat.n_classes == base.n_classes && compat.k == base.k,
            "{}: compat block {:?} disagrees with manifest body (d={}, n_classes={}, k={})",
            path.display(),
            compat,
            base.d,
            base.n_classes,
            base.k
        );

        let mut blob_sha = BTreeMap::new();
        for (name, w) in j.get("weights").map_err(anyhow::Error::from)?.as_obj()? {
            let sha = w
                .opt("sha256")
                .ok_or_else(|| anyhow::anyhow!("{}: weight '{name}' has no sha256", path.display()))?
                .as_str()?
                .to_string();
            blob_sha.insert(name.clone(), sha);
        }
        let mut file_sha = BTreeMap::new();
        if let Some(fs) = j.opt("files_sha256") {
            for (name, v) in fs.as_obj()? {
                file_sha.insert(name.clone(), v.as_str()?.to_string());
            }
        }
        Ok(Self { base, generation, compat, blob_sha, file_sha, self_sha256, raw_sha256 })
    }

    /// True when this artifact can replace an engine serving the
    /// given shape.
    pub fn compatible_with(&self, d: usize, n_classes: usize, k: usize) -> bool {
        self.compat == Compat { d, n_classes, k }
    }

    /// Load the expert set with every blob streamed through a
    /// `HashingReader` — one read pass, hash-verified against the
    /// manifest before any byte is trusted.
    pub fn load_verified_set(&self) -> Result<ExpertSet> {
        // Resolve weight-name-keyed digests to the concrete blob
        // paths the loader will open (store manifests use relative
        // `../../objects/<hex>` files, so key on the joined path).
        let mut by_path: BTreeMap<PathBuf, &str> = BTreeMap::new();
        for (name, info) in &self.base.weights {
            let sha = self
                .blob_sha
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("no recorded sha256 for weight '{name}'"))?;
            by_path.insert(self.base.dir.join(&info.file), sha.as_str());
        }
        let mut read = |path: &Path| -> Result<Vec<u8>> {
            let expect = by_path
                .get(path)
                .ok_or_else(|| anyhow::anyhow!("no recorded sha256 for blob {}", path.display()))?;
            hash::read_verified(path, expect)
        };
        let set = self.base.expert_set_with(&mut read)?;
        set.validate().map_err(|e| anyhow::anyhow!("artifact expert set invalid: {e}"))?;
        Ok(set)
    }

    /// Stream-verify every recorded digest (all weight blobs and all
    /// HLO files) without building an engine.  Used by `dss pack
    /// --check`.  Returns the number of files verified.
    pub fn verify_blobs(&self) -> Result<usize> {
        let mut n = 0;
        for (name, info) in &self.base.weights {
            let expect = self
                .blob_sha
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("no recorded sha256 for weight '{name}'"))?;
            hash::read_verified(&self.base.dir.join(&info.file), expect)?;
            n += 1;
        }
        for (logical, file) in &self.base.files {
            let expect = self
                .file_sha
                .get(logical)
                .ok_or_else(|| anyhow::anyhow!("no recorded sha256 for file '{logical}'"))?;
            hash::read_verified(&self.base.dir.join(file), expect)?;
            n += 1;
        }
        Ok(n)
    }
}

/// Stamp (or re-stamp) an artifact directory as manifest v2: hash
/// every blob and HLO file, attach the compat block, set the
/// generation, and seal with the canonical self-hash.
///
/// `generation`: `Some(g)` forces the ordinal; `None` keeps an
/// existing one (already-v2 manifest) or starts at 1 (v1 manifest).
/// Re-stamping with `None` is byte-idempotent.
pub fn stamp(dir: impl AsRef<Path>, generation: Option<u64>) -> Result<ManifestV2> {
    let dir = dir.as_ref().to_path_buf();
    let path = dir.join("manifest.json");
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("read {}", path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parse {}", path.display()))?;
    let mut m = j.as_obj().map_err(anyhow::Error::from)?.clone();

    let gen = match generation {
        Some(g) => g,
        None => match m.get("generation") {
            Some(g) => g.as_f64()? as u64,
            None => 1,
        },
    };
    anyhow::ensure!(gen >= 1, "generation must be >= 1 (got {gen})");

    // Per-weight blob digests, streamed.
    let weights = m
        .get("weights")
        .ok_or_else(|| anyhow::anyhow!("{}: no weights table", path.display()))?
        .as_obj()?
        .clone();
    let mut stamped_weights = BTreeMap::new();
    for (name, w) in weights {
        let mut wo = w.as_obj()?.clone();
        let file = wo
            .get("file")
            .ok_or_else(|| anyhow::anyhow!("weight '{name}' has no file"))?
            .as_str()?
            .to_string();
        let sha = hash_file(&dir.join(&file))
            .with_context(|| format!("hash blob for weight '{name}'"))?;
        wo.insert("sha256".to_string(), Json::Str(sha));
        stamped_weights.insert(name, Json::Obj(wo));
    }
    m.insert("weights".to_string(), Json::Obj(stamped_weights));

    // HLO file digests.
    let mut files_sha = BTreeMap::new();
    if let Some(files) = m.get("files") {
        for (logical, file) in files.as_obj()?.clone() {
            let sha = hash_file(&dir.join(file.as_str()?))
                .with_context(|| format!("hash file '{logical}'"))?;
            files_sha.insert(logical, Json::Str(sha));
        }
    }
    m.insert("files_sha256".to_string(), Json::Obj(files_sha));

    // Compat block from the manifest body.
    let field = |k: &str| -> Result<usize> {
        Ok(m.get(k)
            .ok_or_else(|| anyhow::anyhow!("{}: no '{k}'", path.display()))?
            .as_usize()?)
    };
    let compat = Json::obj(vec![
        ("d", field("d")?.into()),
        ("n_classes", field("n_classes")?.into()),
        ("k", field("k")?.into()),
    ]);
    m.insert("compat".to_string(), compat);
    m.insert("manifest_version".to_string(), Json::Num(2.0));
    m.insert("generation".to_string(), Json::Num(gen as f64));

    // Seal: self-hash over the canonical rendering with an empty
    // self_sha256 slot, then write exactly that canonical text.
    let sealed = self_hash(&Json::Obj(m.clone()))?;
    m.insert("self_sha256".to_string(), Json::Str(sealed));
    std::fs::write(&path, format!("{}\n", Json::Obj(m)))
        .with_context(|| format!("write {}", path.display()))?;

    // Re-load through the verifying path: proves the stamp is
    // self-consistent before anyone trusts it.
    ManifestV2::load(&dir)
}

fn hash_file(path: &PathBuf) -> Result<String> {
    use std::io::Read;
    let file =
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut reader = hash::HashingReader::new(file);
    let mut chunk = [0u8; 64 * 1024];
    loop {
        let n = reader.read(&mut chunk)?;
        if n == 0 {
            break;
        }
    }
    Ok(hash::hex(&reader.digest()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts::write_artifact_dir;
    use crate::util::rng::Rng;

    fn mk_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dss-manifest2-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn mk_artifact(dir: &Path, seed: u64) -> ExpertSet {
        let mut rng = Rng::new(seed);
        let set = ExpertSet::synthetic(40, 8, 4, 2.0, &mut rng);
        write_artifact_dir(dir, "v2test", &set, &[0.25; 4]).unwrap();
        set
    }

    #[test]
    fn stamp_then_load_verifies() {
        let dir = mk_dir("stamp");
        let set = mk_artifact(&dir, 3);
        // v1 load refuses nothing; v2 load refuses unstamped.
        assert!(ManifestV2::load(&dir).is_err());
        let m2 = stamp(&dir, None).unwrap();
        assert_eq!(m2.generation, 1);
        assert_eq!(m2.compat, Compat { d: 8, n_classes: 40, k: 4 });
        assert_eq!(m2.verify_blobs().unwrap(), 4);
        let loaded = m2.load_verified_set().unwrap();
        assert_eq!(loaded.gate.data, set.gate.data);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stamp_is_idempotent_and_generation_sticks() {
        let dir = mk_dir("idem");
        mk_artifact(&dir, 4);
        stamp(&dir, Some(7)).unwrap();
        let first = std::fs::read(dir.join("manifest.json")).unwrap();
        let again = stamp(&dir, None).unwrap();
        assert_eq!(again.generation, 7);
        let second = std::fs::read(dir.join("manifest.json")).unwrap();
        assert_eq!(first, second, "re-stamp must be byte-identical");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_tamper_is_rejected() {
        let dir = mk_dir("tamper");
        mk_artifact(&dir, 5);
        stamp(&dir, Some(2)).unwrap();
        let path = dir.join("manifest.json");
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one bit mid-file: either the parse breaks or the
        // canonical rendering changes and the self-hash catches it.
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert!(ManifestV2::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn blob_tamper_is_rejected_at_stream_time() {
        let dir = mk_dir("blobflip");
        mk_artifact(&dir, 6);
        let m2 = stamp(&dir, None).unwrap();
        let blob = dir.join("packed.bin");
        let mut bytes = std::fs::read(&blob).unwrap();
        bytes[17] ^= 0x01;
        std::fs::write(&blob, &bytes).unwrap();
        // Structural load still passes (manifest untouched)…
        let m2b = ManifestV2::load(&dir).unwrap();
        assert_eq!(m2b.raw_sha256, m2.raw_sha256);
        // …but the streaming verify names the file.
        let err = m2b.load_verified_set().unwrap_err();
        assert!(format!("{err:#}").contains("packed.bin"), "{err:#}");
        assert!(format!("{err:#}").contains("sha256 mismatch"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
