//! Sparse-expert storage: the packed per-expert class subsets that are
//! the paper's second level of sparsity.
//!
//! An [`ExpertSet`] owns, per expert k: the packed embedding rows
//! (|v_k| × d, padded to `p`), the global class id of each packed row,
//! and the valid count.  This mirrors the export contract of
//! `python/compile/model.py::ds_pack` byte-for-byte.

use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// One sparse expert: a packed view of a class subset.
#[derive(Clone, Debug)]
pub struct SparseExpert {
    /// (p, d) packed rows; rows past `valid` are zero padding.
    pub weights: Matrix,
    /// global class id per packed row; -1 past `valid`.
    pub class_ids: Vec<i32>,
    pub valid: usize,
    /// Sorted copy of the valid class ids, built at construction:
    /// O(log |v_k|) membership and linear-merge overlap instead of a
    /// per-class linear scan.  Call [`rebuild_index`] after mutating
    /// `class_ids`/`valid` directly.
    ///
    /// [`rebuild_index`]: SparseExpert::rebuild_index
    sorted: Vec<i32>,
}

impl SparseExpert {
    /// Build an expert and its sorted class index.
    pub fn new(weights: Matrix, class_ids: Vec<i32>, valid: usize) -> Self {
        let mut e = Self { weights, class_ids, valid, sorted: Vec::new() };
        e.rebuild_index();
        e
    }

    /// Re-derive the sorted membership index after a direct mutation of
    /// `class_ids` or `valid`.
    pub fn rebuild_index(&mut self) {
        self.sorted.clear();
        self.sorted.extend_from_slice(&self.class_ids[..self.valid]);
        self.sorted.sort_unstable();
    }

    pub fn size(&self) -> usize {
        self.valid
    }

    /// The class ids actually present (no padding), in packed order.
    pub fn classes(&self) -> &[i32] {
        &self.class_ids[..self.valid]
    }

    /// Membership via binary search over the sorted index.
    pub fn contains(&self, class: u32) -> bool {
        self.sorted.binary_search(&(class as i32)).is_ok()
    }

    /// Number of classes shared with `other` — a sorted-merge walk,
    /// O(|v_a| + |v_b|); overlap accounting for planners and eval.
    pub fn overlap(&self, other: &SparseExpert) -> usize {
        let (a, b) = (&self.sorted, &other.sorted);
        let (mut i, mut j, mut n) = (0usize, 0usize, 0usize);
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    n += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        n
    }
}

/// The full two-level structure: gating weights + K sparse experts.
#[derive(Clone, Debug)]
pub struct ExpertSet {
    /// (K, d) gating matrix.
    pub gate: Matrix,
    pub experts: Vec<SparseExpert>,
    /// total number of classes N in the original output space.
    pub n_classes: usize,
}

impl ExpertSet {
    pub fn k(&self) -> usize {
        self.experts.len()
    }

    pub fn dim(&self) -> usize {
        self.gate.cols
    }

    /// Padded packed size (uniform across experts by construction).
    pub fn p(&self) -> usize {
        self.experts.first().map(|e| e.weights.rows).unwrap_or(0)
    }

    pub fn expert_sizes(&self) -> Vec<usize> {
        self.experts.iter().map(|e| e.valid).collect()
    }

    /// Redundancy of class c: number of experts containing it (paper
    /// Fig. 5b's y-axis).
    pub fn redundancy(&self) -> Vec<u32> {
        let mut r = vec![0u32; self.n_classes];
        for e in &self.experts {
            for &c in e.classes() {
                if c >= 0 {
                    r[c as usize] += 1;
                }
            }
        }
        r
    }

    /// Average number of experts per class, the paper's `m`.
    pub fn mean_redundancy(&self) -> f64 {
        let r = self.redundancy();
        r.iter().map(|&x| x as f64).sum::<f64>() / r.len().max(1) as f64
    }

    /// Every class must live in >= 1 expert (footnote-4 invariant).
    pub fn validate(&self) -> Result<(), String> {
        let d = self.dim();
        for (k, e) in self.experts.iter().enumerate() {
            if e.weights.cols != d {
                return Err(format!("expert {k}: dim {} != gate dim {d}", e.weights.cols));
            }
            if e.valid > e.weights.rows {
                return Err(format!("expert {k}: valid {} > p {}", e.valid, e.weights.rows));
            }
            for (i, &c) in e.class_ids.iter().enumerate() {
                let in_range = c >= 0 && (c as usize) < self.n_classes;
                if i < e.valid && !in_range {
                    return Err(format!("expert {k}: row {i} bad class id {c}"));
                }
                if i >= e.valid && c != -1 {
                    return Err(format!("expert {k}: padding row {i} has id {c}"));
                }
            }
            // padding rows must be zero so PJRT batched softmax can mask
            for r in e.valid..e.weights.rows {
                if e.weights.row(r).iter().any(|&x| x != 0.0) {
                    return Err(format!("expert {k}: nonzero padding row {r}"));
                }
            }
        }
        let red = self.redundancy();
        if let Some(c) = red.iter().position(|&x| x == 0) {
            return Err(format!("class {c} not covered by any expert"));
        }
        Ok(())
    }

    /// Theoretical FLOPs speedup |V| / (Σ_k |v_k|·u_k + K)  (paper §2.3).
    pub fn speedup(&self, utilization: &[f64]) -> f64 {
        assert_eq!(utilization.len(), self.k());
        let expected: f64 = self
            .experts
            .iter()
            .zip(utilization)
            .map(|(e, &u)| e.valid as f64 * u)
            .sum::<f64>()
            + self.k() as f64;
        self.n_classes as f64 / expected
    }

    /// Build a synthetic ExpertSet with the distributional shape of a
    /// trained model: expert sizes ≈ N·m/K (balanced), frequent classes
    /// (low ids under a Zipf workload) replicated into more experts.
    ///
    /// Used by the paper-scale latency benches where training at full
    /// (N, d) is out of budget but the *sparsity statistics* of the
    /// trained small-scale models transfer (DESIGN.md §5).
    pub fn synthetic(
        n_classes: usize,
        d: usize,
        k: usize,
        mean_redundancy: f64,
        rng: &mut Rng,
    ) -> ExpertSet {
        assert!(k >= 1 && mean_redundancy >= 1.0);
        let total_slots = (n_classes as f64 * mean_redundancy) as usize;
        let per_expert = (total_slots + k - 1) / k;
        let p = per_expert.next_multiple_of(8);
        // Replication count per class: frequent (low-id) classes get more
        // copies, matching Fig. 5b's frequency↔redundancy correlation.
        let extra = total_slots - n_classes;
        let mut copies = vec![1usize; n_classes];
        // distribute extras with a 1/rank profile
        let mut weights: Vec<f64> = (0..n_classes).map(|i| 1.0 / (i + 1) as f64).collect();
        let wsum: f64 = weights.iter().sum();
        for w in weights.iter_mut() {
            *w /= wsum;
        }
        let mut given = 0usize;
        for c in 0..n_classes {
            if given >= extra {
                break;
            }
            let want = ((extra as f64) * weights[c]).round() as usize;
            let add = want.min(extra - given).min(k - 1);
            copies[c] += add;
            given += add;
        }
        // second pass: hand out any remainder in rank order (the rounding
        // above drops most of the tail's fractional shares)
        let mut c = 0usize;
        while given < extra && k > 1 {
            if copies[c] < k {
                copies[c] += 1;
                given += 1;
            }
            c = (c + 1) % n_classes;
        }
        // round-robin assignment of copies to experts
        let mut members: Vec<Vec<i32>> = vec![Vec::with_capacity(per_expert); k];
        let mut next = 0usize;
        for c in 0..n_classes {
            let mut used = vec![false; k];
            for _ in 0..copies[c] {
                // find next expert not yet holding c and not full
                let mut tries = 0;
                loop {
                    let e = next % k;
                    next += 1;
                    tries += 1;
                    if (!used[e] && members[e].len() < p) || tries > 2 * k {
                        used[e] = true;
                        members[e].push(c as i32);
                        break;
                    }
                }
            }
        }
        let experts = members
            .into_iter()
            .map(|ids| {
                let valid = ids.len();
                let mut w = Matrix::zeros(p, d);
                for r in 0..valid {
                    let row = rng.normal_vec(d, 0.05);
                    w.row_mut(r).copy_from_slice(&row);
                }
                let mut class_ids = ids;
                class_ids.resize(p, -1);
                SparseExpert::new(w, class_ids, valid)
            })
            .collect();
        ExpertSet {
            gate: Matrix::random(k, d, rng, 0.05),
            experts,
            n_classes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_set() -> ExpertSet {
        let mut rng = Rng::new(3);
        ExpertSet::synthetic(64, 8, 4, 1.3, &mut rng)
    }

    #[test]
    fn synthetic_validates() {
        tiny_set().validate().unwrap();
    }

    #[test]
    fn synthetic_redundancy_close_to_target() {
        let mut rng = Rng::new(4);
        let es = ExpertSet::synthetic(1000, 16, 8, 1.5, &mut rng);
        es.validate().unwrap();
        let m = es.mean_redundancy();
        assert!((m - 1.5).abs() < 0.2, "mean redundancy {m}");
    }

    #[test]
    fn frequent_classes_more_redundant() {
        let mut rng = Rng::new(5);
        let es = ExpertSet::synthetic(1000, 16, 8, 1.5, &mut rng);
        let r = es.redundancy();
        let head: f64 = r[..50].iter().map(|&x| x as f64).sum::<f64>() / 50.0;
        let tail: f64 = r[900..].iter().map(|&x| x as f64).sum::<f64>() / 100.0;
        assert!(head > tail, "head {head} tail {tail}");
    }

    #[test]
    fn speedup_formula() {
        let es = tiny_set();
        let k = es.k();
        let uniform = vec![1.0 / k as f64; k];
        let s = es.speedup(&uniform);
        let mean_size: f64 =
            es.expert_sizes().iter().map(|&x| x as f64).sum::<f64>() / k as f64;
        let want = 64.0 / (mean_size + k as f64);
        assert!((s - want).abs() < 1e-9);
    }

    #[test]
    fn validate_catches_uncovered_class() {
        let mut es = tiny_set();
        // remove class 0 everywhere
        for e in es.experts.iter_mut() {
            if let Some(pos) = e.class_ids[..e.valid].iter().position(|&c| c == 0) {
                let last = e.valid - 1;
                e.class_ids.swap(pos, last);
                e.class_ids[last] = -1;
                let row: Vec<f32> = e.weights.row(last).to_vec();
                e.weights.row_mut(pos).copy_from_slice(&row);
                for x in e.weights.row_mut(last) {
                    *x = 0.0;
                }
                e.valid -= 1;
            }
        }
        assert!(es.validate().is_err());
    }

    #[test]
    fn validate_catches_nonzero_padding() {
        let mut es = tiny_set();
        let e = &mut es.experts[0];
        if e.valid < e.weights.rows {
            let r = e.valid;
            e.weights.row_mut(r)[0] = 1.0;
            assert!(es.validate().is_err());
        }
    }

    #[test]
    fn contains_and_classes() {
        let es = tiny_set();
        let e = &es.experts[0];
        let c = e.classes()[0] as u32;
        assert!(e.contains(c));
        assert_eq!(e.classes().len(), e.size());
    }

    #[test]
    fn contains_matches_linear_scan_for_all_classes() {
        let es = tiny_set();
        for e in &es.experts {
            for c in 0..es.n_classes as u32 {
                assert_eq!(
                    e.contains(c),
                    e.classes().contains(&(c as i32)),
                    "class {c}"
                );
            }
        }
    }

    #[test]
    fn rebuild_index_tracks_mutation() {
        let mut es = tiny_set();
        let e = &mut es.experts[0];
        let c = e.classes()[0];
        // drop the first class by swapping it out, then re-index
        let last = e.valid - 1;
        e.class_ids.swap(0, last);
        e.class_ids[last] = -1;
        e.valid -= 1;
        e.rebuild_index();
        assert!(!e.contains(c as u32));
        assert_eq!(e.sorted.len(), e.valid);
    }

    #[test]
    fn overlap_matches_brute_force() {
        let es = tiny_set();
        let (a, b) = (&es.experts[0], &es.experts[1]);
        let brute = a
            .classes()
            .iter()
            .filter(|c| b.classes().contains(c))
            .count();
        assert_eq!(a.overlap(b), brute);
        assert_eq!(b.overlap(a), brute);
        assert_eq!(a.overlap(a), a.size());
    }
}
