//! Minimal JSON substrate (vendor tree has no `serde`): a recursive-descent
//! parser and a writer, enough for artifact manifests, experiment result
//! files and the line-delimited wire protocol of the serving example.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (manifest sizes fit exactly).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("unexpected end of input at byte {0}")]
    Eof(usize),
    #[error("unexpected character '{0}' at byte {1}")]
    Unexpected(char, usize),
    #[error("invalid number at byte {0}")]
    BadNumber(usize),
    #[error("invalid escape at byte {0}")]
    BadEscape(usize),
    #[error("trailing characters at byte {0}")]
    Trailing(usize),
    #[error("type error: expected {0}")]
    Type(&'static str),
    #[error("missing key: {0}")]
    Missing(String),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(JsonError::Type("number")),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type("string")),
        }
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Type("bool")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(JsonError::Type("array")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(JsonError::Type("object")),
        }
    }

    /// `obj["key"]` with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    /// Optional key access.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::Unexpected(self.b[self.i] as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.b[self.i] as char, self.i))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::Eof(self.i));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(JsonError::BadEscape(self.i - 1)),
                    }
                }
                _ => {
                    // Re-walk UTF-8: push raw bytes via the source slice.
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| JsonError::BadEscape(start))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n \"k\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(j.get("k").unwrap().usize_vec().unwrap(), vec![1, 2]);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let orig = Json::Str("a\"b\\c\nd\te\u{1f600}".into());
        let parsed = Json::parse(&orig.to_string()).unwrap();
        assert_eq!(parsed, orig);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""A""#).unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn roundtrip_random_object() {
        let j = Json::obj(vec![
            ("name", "lm".into()),
            ("sizes", Json::arr_usize(&[1, 8, 32])),
            ("ratio", 23.86.into()),
            ("flag", true.into()),
            ("nested", Json::obj(vec![("x", Json::Null)])),
        ]);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn typed_access_errors() {
        let j = Json::parse(r#"{"a":1}"#).unwrap();
        assert!(j.get("missing").is_err());
        assert!(j.get("a").unwrap().as_str().is_err());
        assert_eq!(j.get("a").unwrap().as_usize().unwrap(), 1);
    }
}
