//! SVD-softmax baseline (Shim et al., NeurIPS 2017) — including the SVD
//! itself, computed from scratch with one-sided Jacobi (no LAPACK in the
//! offline vendor tree).
//!
//! Method: factor W = B·Vᵀ with B = U·Σ (N×d, columns ordered by
//! descending singular value) and V orthogonal (d×d).  At query time:
//!   1. h̃ = Vᵀ·h                              (d² FLOPs)
//!   2. preview logits  = B[:, :w]·h̃[:w]      (2·N·w)
//!   3. refine the top ρ·N preview candidates with full-width rows
//!   4. softmax over preview logits with refined entries patched in.
//!
//! The paper's SVD-5 / SVD-10 configurations are window width 16 and
//! refinement of the top 5% / 10% classes (§3.5).

use crate::model::SoftmaxEngine;
use crate::query::{with_scratch, MatrixView, TopKBuf};
use crate::tensor::kernel;
use crate::tensor::{dot, Matrix};

pub struct SvdSoftmax {
    /// B = U·Σ, N×d, columns sorted by descending singular value.
    pub b: Matrix,
    /// V, d×d (logits = B · Vᵀ h).
    pub v: Matrix,
    pub window: usize,
    pub refine_frac: f64,
    pub singular_values: Vec<f32>,
    /// Construction-time kernel selection (see `DsSoftmax::sel`): only
    /// the preview matmul dispatches on it — the rotation and the
    /// full-width refine `dot`s keep their exact summation orders.
    pub sel: kernel::KernelSel,
}

impl SvdSoftmax {
    /// Factor `w` (N×d) and build the engine.
    pub fn new(w: &Matrix, window: usize, refine_frac: f64) -> Self {
        let (b, v, s) = jacobi_svd(w, 30, 1e-9);
        Self::from_parts(b, v, window, refine_frac, s)
    }

    /// Assemble from an existing factorization W = B·Vᵀ (e.g. the
    /// subsampled SVD the latency bench uses at Wiki-2 scale).
    pub fn from_parts(
        b: Matrix,
        v: Matrix,
        window: usize,
        refine_frac: f64,
        singular_values: Vec<f32>,
    ) -> Self {
        let window = window.min(b.cols);
        Self { b, v, window, refine_frac, singular_values, sel: kernel::selected() }
    }

    fn n_refine(&self) -> usize {
        ((self.b.rows as f64) * self.refine_frac).ceil() as usize
    }

    /// h̃ = Vᵀ h into caller scratch.  Deliberately the seed's scalar
    /// accumulation (not the 8-lane `dot`): the rotation's summation
    /// order is part of the engine's bit-exactness contract across
    /// this kernel rewrite — the preview/refine stages downstream are
    /// `dot`-based and run through the kernel unchanged.
    fn rotate_into(&self, h: &[f32], out: &mut [f32]) {
        let d = self.v.rows;
        for (j, o) in out[..d].iter_mut().enumerate() {
            let mut s = 0.0;
            for i in 0..d {
                s += self.v.row(i)[j] * h[i];
            }
            *o = s;
        }
    }
}

impl SoftmaxEngine for SvdSoftmax {
    /// Batched preview → refine → top-k: the window-`w` preview runs
    /// through the tiled kernel (B's preview columns streamed once per
    /// row tile), refinement patches the top candidates at full width,
    /// and the tail is fused — the exp-sum is taken over the whole
    /// preview+refined row while selection and normalization touch
    /// only the refined candidates.  The rotation stays the seed's
    /// scalar loop for bit-exactness (see `rotate_into`).
    fn query_batch(&self, hs: MatrixView<'_>, k: usize, out: &mut TopKBuf) {
        assert_eq!(hs.cols, self.b.cols, "row width vs model dim");
        out.reset(hs.rows, k);
        if hs.rows == 0 {
            return;
        }
        let n = self.b.rows;
        let d = self.b.cols;
        let w = self.window;
        let nr = self.n_refine().max(k).min(n);
        with_scratch(|s| {
            let crate::query::QueryScratch { heap, heap2, tile, rot, cand, .. } = s;
            heap.set_k(k);
            heap2.set_k(nr);
            let tr = self.sel.tile_rows();
            tile.resize(tr * n, 0.0);
            // per-tile rotation keeps scratch model-bounded (O(tile·d),
            // not O(batch·d)) like every other engine
            rot.resize(tr * d, 0.0);
            for t0 in (0..hs.rows).step_by(tr) {
                let th = tr.min(hs.rows - t0);
                // stage 1: h̃ = Vᵀ·h per row (bit-exact scalar rotation,
                // see `rotate_into`)
                for i in 0..th {
                    self.rotate_into(hs.row(t0 + i), &mut rot[i * d..(i + 1) * d]);
                }
                // stage 2: preview logits over the top-w singular
                // directions (reduce over the h̃ prefix: d = w < stride)
                kernel::matmul_nt_strided_into_sel(
                    self.sel,
                    rot,
                    d,
                    &self.b.data,
                    self.b.cols,
                    th,
                    n,
                    w,
                    tile,
                    n,
                );
                for i in 0..th {
                    let ht = &rot[i * d..(i + 1) * d];
                    let row = &mut tile[i * n..(i + 1) * n];
                    // candidates: top-nr preview logits, descending
                    heap2.clear();
                    heap2.push_slice(row);
                    cand.clear();
                    cand.extend(heap2.sorted_in_place().iter().map(|&(_, c)| c));
                    // stage 3: refine candidates at full width
                    for &c in cand.iter() {
                        row[c as usize] = dot(self.b.row(c as usize), ht);
                    }
                    // stage 4: fused tail — normalize against the whole
                    // row, select only among refined candidates (the
                    // preview-only logits are approximations)
                    let (m, sum) = kernel::max_and_expsum(row);
                    let inv = 1.0 / sum;
                    heap.clear();
                    for &c in cand.iter() {
                        heap.push(row[c as usize], c);
                    }
                    kernel::emit_normalized(heap, m, inv, |id, p| out.push(t0 + i, id, p));
                }
            }
        });
    }

    fn flops_per_query(&self) -> u64 {
        crate::flops::svd_softmax(self.b.rows, self.b.cols, self.window, self.refine_frac)
    }

    fn n_classes(&self) -> usize {
        self.b.rows
    }

    fn dim(&self) -> usize {
        self.b.cols
    }

    fn name(&self) -> &'static str {
        "svd-softmax"
    }
}

/// One-sided Jacobi SVD of `a` (N×d, N >= d): returns (B = U·Σ, V, σ)
/// with B's columns ordered by descending σ.  Rotations are applied to
/// column pairs until the off-diagonal Gram mass is negligible.
pub fn jacobi_svd(a: &Matrix, max_sweeps: usize, tol: f64) -> (Matrix, Matrix, Vec<f32>) {
    let n = a.rows;
    let d = a.cols;
    // column-major copy of A for cache-friendly column rotations
    let mut cols: Vec<Vec<f32>> = (0..d)
        .map(|j| (0..n).map(|i| a.row(i)[j]).collect())
        .collect();
    let mut v = vec![vec![0.0f32; d]; d];
    for (i, row) in v.iter_mut().enumerate() {
        row[i] = 1.0;
    }

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..d {
            for q in (p + 1)..d {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..n {
                    let x = cols[p][i] as f64;
                    let y = cols[q][i] as f64;
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                off += apq * apq;
                if apq.abs() < tol * (app * aqq).sqrt().max(1e-30) {
                    continue;
                }
                // Jacobi rotation angle
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                // rotate data columns
                let (left, right) = cols.split_at_mut(q);
                let (cp, cq) = (&mut left[p], &mut right[0]);
                for i in 0..n {
                    let x = cp[i];
                    let y = cq[i];
                    cp[i] = cf * x - sf * y;
                    cq[i] = sf * x + cf * y;
                }
                // rotate V rows (V accumulates the same rotations)
                for row in v.iter_mut() {
                    let x = row[p];
                    let y = row[q];
                    row[p] = cf * x - sf * y;
                    row[q] = sf * x + cf * y;
                }
            }
        }
        if off.sqrt() < tol {
            break;
        }
    }

    // singular values = column norms; sort descending
    let mut order: Vec<usize> = (0..d).collect();
    let norms: Vec<f64> = cols
        .iter()
        .map(|c| c.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut b = Matrix::zeros(n, d);
    let mut vm = Matrix::zeros(d, d);
    let mut sigma = Vec::with_capacity(d);
    for (new_j, &old_j) in order.iter().enumerate() {
        sigma.push(norms[old_j] as f32);
        for i in 0..n {
            b.row_mut(i)[new_j] = cols[old_j][i];
        }
        for i in 0..d {
            vm.row_mut(i)[new_j] = v[i][old_j];
        }
    }
    (b, vm, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::full::FullSoftmax;
    use crate::util::rng::Rng;

    #[test]
    fn svd_reconstructs_w() {
        let mut rng = Rng::new(1);
        let w = Matrix::random(40, 8, &mut rng, 1.0);
        let (b, v, _s) = jacobi_svd(&w, 30, 1e-10);
        // W = B Vᵀ  →  W[i][j] = Σ_k B[i][k] V[j][k]
        for i in 0..40 {
            for j in 0..8 {
                let got: f32 = (0..8).map(|k| b.row(i)[k] * v.row(j)[k]).sum();
                assert!((got - w.row(i)[j]).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn svd_v_orthogonal() {
        let mut rng = Rng::new(2);
        let w = Matrix::random(30, 6, &mut rng, 1.0);
        let (_b, v, _s) = jacobi_svd(&w, 30, 1e-10);
        for i in 0..6 {
            for j in 0..6 {
                let got: f32 = (0..6).map(|k| v.row(k)[i] * v.row(k)[j]).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((got - want).abs() < 1e-3, "({i},{j}) {got}");
            }
        }
    }

    #[test]
    fn singular_values_descending() {
        let mut rng = Rng::new(3);
        let w = Matrix::random(50, 10, &mut rng, 1.0);
        let (_b, _v, s) = jacobi_svd(&w, 30, 1e-10);
        for win in s.windows(2) {
            assert!(win[0] >= win[1] - 1e-4);
        }
    }

    #[test]
    fn svd_softmax_high_refine_matches_full() {
        // refine everything → exact
        let mut rng = Rng::new(4);
        let w = Matrix::random(128, 16, &mut rng, 1.0);
        let full = FullSoftmax::new(w.clone());
        let svd = SvdSoftmax::new(&w, 16, 1.0);
        for _ in 0..10 {
            let h = rng.normal_vec(16, 1.0);
            let a: Vec<u32> = full.query(&h, 5).iter().map(|&(c, _)| c).collect();
            let b: Vec<u32> = svd.query(&h, 5).iter().map(|&(c, _)| c).collect();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn svd_softmax_small_window_mostly_right() {
        // Trained softmax embeddings have decaying spectra (that is the
        // premise of SVD-softmax); build a rank-8-dominant W + noise.
        let mut rng = Rng::new(5);
        let a = Matrix::random(512, 8, &mut rng, 1.0);
        let b = Matrix::random(32, 8, &mut rng, 1.0);
        let mut w = a.matmul_nt(&b); // (512, 32), rank ~8
        for x in w.data.iter_mut() {
            *x += rng.normal_f32(0.0, 0.05);
        }
        let full = FullSoftmax::new(w.clone());
        let svd = SvdSoftmax::new(&w, 8, 0.10);
        let mut hit = 0;
        let trials = 50;
        for _ in 0..trials {
            let h = rng.normal_vec(32, 1.0);
            let a = full.query(&h, 1)[0].0;
            let b = svd.query(&h, 1)[0].0;
            hit += (a == b) as usize;
        }
        assert!(hit * 100 / trials >= 80, "top-1 agreement {hit}/{trials}");
    }

    #[test]
    fn flops_cheaper_than_full() {
        let mut rng = Rng::new(6);
        let w = Matrix::random(1000, 64, &mut rng, 1.0);
        let svd = SvdSoftmax::new(&w, 16, 0.05);
        assert!(svd.flops_per_query() < crate::flops::full_softmax(1000, 64));
    }
}
