"""DS-Softmax layer semantics (model.py) — Eq. 1/2, pruning, packing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref


@pytest.fixture(scope="module")
def small():
    key = jax.random.PRNGKey(0)
    params, state = M.ds_init(key, k=4, n=64, d=16)
    h = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    y = jax.random.randint(jax.random.PRNGKey(2), (32,), 0, 64)
    return params, state, h, y


def test_train_forward_is_logprob(small):
    params, state, h, y = small
    logp, aux = M.ds_train_forward(params, state, h)
    p = np.exp(np.asarray(logp))
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
    assert aux["top1"].shape == (32,)
    assert aux["gate_value"].shape == (32,)


def test_gate_value_matches_gate_ref(small):
    params, state, h, _ = small
    _, aux = M.ds_train_forward(params, state, h)
    gp, top1 = ref.gate_ref(h, params.u)
    np.testing.assert_array_equal(np.asarray(aux["top1"]), np.asarray(top1))
    gv = np.take_along_axis(np.asarray(gp), np.asarray(top1)[:, None], 1)[:, 0]
    np.testing.assert_allclose(np.asarray(aux["gate_value"]), gv, rtol=1e-6)


def test_masked_classes_get_zero_prob(small):
    params, state, h, _ = small
    mask = np.ones((4, 64), np.float32)
    mask[:, 10] = 0.0  # class 10 pruned everywhere
    logp, _ = M.ds_train_forward(params, M.DsState(jnp.asarray(mask)), h)
    p = np.exp(np.asarray(logp))
    assert (p[:, 10] < 1e-8).all()


def test_prune_removes_small_rows(small):
    params, state, _, _ = small
    w = np.asarray(params.w).copy()
    w[0, :32] *= 1e-4  # half of expert 0's rows get tiny
    p2, s2 = M.ds_prune(M.DsParams(params.u, jnp.asarray(w)), state, gamma=0.01)
    m = np.asarray(s2.mask)
    assert m[0, :32].sum() <= 1  # possibly one protected orphan
    assert m[0, 32:].sum() == 32


def test_prune_footnote4_every_class_survives(small):
    params, state, _, _ = small
    # Make everything tiny: naive pruning would empty all experts.
    p2, s2 = M.ds_prune(M.DsParams(params.u, params.w * 1e-6), state, gamma=0.01)
    m = np.asarray(s2.mask)
    assert (m.sum(axis=0) >= 1).all()  # each class alive in >= 1 expert


def test_prune_idempotent(small):
    params, state, _, _ = small
    p1, s1 = M.ds_prune(params, state, gamma=0.02)
    p2, s2 = M.ds_prune(p1, s1, gamma=0.02)
    np.testing.assert_array_equal(np.asarray(s1.mask), np.asarray(s2.mask))


def test_prune_zeroes_weights(small):
    params, state, _, _ = small
    p1, s1 = M.ds_prune(params, state, gamma=0.03)
    w = np.asarray(p1.w)
    m = np.asarray(s1.mask)
    assert (np.abs(w[m == 0]).max() if (m == 0).any() else 0.0) == 0.0


def test_mitosis_doubles_and_inherits(small):
    params, state, _, _ = small
    p1, s1 = M.ds_prune(params, state, gamma=0.03)
    p2, s2 = M.ds_mitosis_split(p1, s1, jax.random.PRNGKey(3))
    assert p2.u.shape[0] == 8 and p2.w.shape[0] == 8
    m1, m2 = np.asarray(s1.mask), np.asarray(s2.mask)
    np.testing.assert_array_equal(m2[:4], m1)
    np.testing.assert_array_equal(m2[4:], m1)
    # children differ but average to the parent
    w = np.asarray(p2.w)
    np.testing.assert_allclose((w[:4] + w[4:]) / 2, np.asarray(p1.w), atol=1e-6)


def test_pack_roundtrip(small):
    params, state, h, _ = small
    p1, s1 = M.ds_prune(params, state, gamma=0.03)
    packed = M.ds_pack(p1, s1, pad_to=8)
    k, p, d = packed.weights.shape
    assert p % 8 == 0
    m = np.asarray(s1.mask)
    for i in range(k):
        ids = packed.class_ids[i]
        v = packed.valid[i]
        assert (ids[:v] >= 0).all() and (ids[v:] == -1).all()
        assert set(ids[:v].tolist()) == set(np.nonzero(m[i])[0].tolist())
        # packed rows equal the surviving dense rows
        np.testing.assert_array_equal(
            packed.weights[i, :v], np.asarray(p1.w)[i, ids[:v]]
        )
        assert (packed.weights[i, v:] == 0).all()


def test_packed_inference_matches_dense_restricted(small):
    """Packed top-k equals dense masked softmax top-k."""
    params, state, h, _ = small
    p1, s1 = M.ds_prune(params, state, gamma=0.03)
    packed = M.ds_pack(p1, s1)
    top1, tv, tc = M.ds_infer(packed, h, 5)
    # dense path
    logp, aux = M.ds_train_forward(p1, s1, h)
    np.testing.assert_array_equal(np.asarray(top1), np.asarray(aux["top1"]))
    dense_top = np.asarray(jax.lax.top_k(logp, 5)[1])
    tc = np.asarray(tc)
    for b in range(h.shape[0]):
        assert set(tc[b]) == set(dense_top[b]), b


def test_speedup_formula():
    packed = M.Packed(
        u=np.zeros((2, 4), np.float32),
        weights=np.zeros((2, 8, 4), np.float32),
        class_ids=np.stack([np.arange(8), np.arange(8, 16)]).astype(np.int32),
        valid=np.array([8, 8], np.int32),
    )
    # N=16, uniform utilization: 16 / (8 + 2) = 1.6
    s = M.ds_speedup(packed, np.array([0.5, 0.5]))
    np.testing.assert_allclose(s, 1.6)


def test_losses_gradients_flow(small):
    params, state, h, y = small

    def loss_fn(p):
        logp, aux = M.ds_train_forward(p, state, h)
        lt = M.ds_task_loss(logp, y)
        ll, lb, le = M.ds_losses(p, state, aux, 0.01)
        return lt + 0.1 * ll + 10.0 * lb + 0.1 * le

    g = jax.grad(loss_fn)(params)
    assert float(jnp.abs(g.u).sum()) > 0  # gate receives gradient
    assert float(jnp.abs(g.w).sum()) > 0
