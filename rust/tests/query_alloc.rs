//! Proof of the "zero-allocation" claim: once the per-thread scratch
//! and the caller-owned `TopKBuf` are warm, `query_batch` on the native
//! DS engine performs **no** heap allocation.  Verified with a counting
//! global allocator; this test lives alone in its own binary so no
//! concurrent test can perturb the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use std::sync::Arc;

use ds_softmax::coordinator::{Metrics, NativeBatchEngine};
use ds_softmax::model::dssoftmax::DsSoftmax;
use ds_softmax::model::SoftmaxEngine;
use ds_softmax::obs::trace::{self, Stage};
use ds_softmax::query::{MatrixView, Route, TopKBuf};
use ds_softmax::runtime::reload::EngineCell;
use ds_softmax::shard::{ShardPlan, ShardedEngine};
use ds_softmax::sparse::ExpertSet;
use ds_softmax::util::rng::Rng;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn count_allocs(f: impl FnOnce()) -> u64 {
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    f();
    COUNTING.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn warm_query_batch_does_not_allocate() {
    let mut rng = Rng::new(7);
    let ds = DsSoftmax::new(ExpertSet::synthetic(512, 32, 8, 1.2, &mut rng));
    let bsz = 16usize;
    let packed: Vec<f32> = (0..bsz).flat_map(|_| rng.normal_vec(32, 1.0)).collect();
    let view = MatrixView::new(&packed, bsz, 32);
    let mut out = TopKBuf::new();
    let mut routes = vec![Route::empty(); bsz];

    // warm: first call grows the thread-local scratch and the arena
    ds.query_batch(view, 10, &mut out);
    ds.route_batch(view, &mut routes);

    // steady state: zero allocations
    let n = count_allocs(|| {
        ds.query_batch(view, 10, &mut out);
        std::hint::black_box(&out);
    });
    assert_eq!(n, 0, "warm query_batch allocated {n} times");

    let n = count_allocs(|| {
        ds.route_batch(view, &mut routes);
        std::hint::black_box(&routes);
    });
    assert_eq!(n, 0, "warm route_batch allocated {n} times");

    // the expert-grouped flush path the coordinator uses is warm-clean too
    let gates = vec![0.5f32; bsz];
    let engine = NativeBatchEngine::new(DsSoftmax::new(ds.set.clone()));
    engine
        .run_expert_batch(1, view, &gates, 10, &mut out)
        .unwrap();
    let n = count_allocs(|| {
        engine
            .run_expert_batch(1, view, &gates, 10, &mut out)
            .expect("run_expert_batch");
        std::hint::black_box(&out);
    });
    assert_eq!(n, 0, "warm run_expert_batch allocated {n} times");

    // the sharded engine's serial scatter/merge path is warm-clean too:
    // routes, per-shard counting-sort workspace, per-expert row packs
    // and both result arenas all come from pooled scratch
    let sharded = ShardedEngine::new(ds.set.clone(), ShardPlan::greedy(&ds.set, 4))
        .expect("sharded engine");
    let mut sh_out = TopKBuf::new();
    sharded.query_batch(view, 10, &mut sh_out); // warm scratch pool
    sharded.query_batch(view, 10, &mut sh_out); // steady-state shapes
    let n = count_allocs(|| {
        sharded.query_batch(view, 10, &mut sh_out);
        std::hint::black_box(&sh_out);
    });
    assert_eq!(n, 0, "warm sharded query_batch allocated {n} times");

    // the coordinator's sharded flush path (expert → shard-local
    // engine, inline) is warm-clean as well
    sharded
        .run_expert_batch(1, view, &gates, 10, &mut sh_out)
        .expect("sharded expert batch");
    let n = count_allocs(|| {
        sharded
            .run_expert_batch(1, view, &gates, 10, &mut sh_out)
            .expect("sharded expert batch");
        std::hint::black_box(&sh_out);
    });
    assert_eq!(n, 0, "warm sharded run_expert_batch allocated {n} times");

    // sharded results remain identical to the unsharded engine after
    // the counted runs
    let mut ref_out = TopKBuf::new();
    ds.query_batch(view, 10, &mut ref_out);
    sharded.query_batch(view, 10, &mut sh_out);
    for r in 0..bsz {
        assert_eq!(sh_out.row_vec(r), ref_out.row_vec(r), "sharded row {r}");
    }

    // the live-reload read path is warm-clean too: pinning a
    // generation (`EngineHandle::load`) is pure refcount traffic, so a
    // warm query through the handle allocates nothing...
    let cell = EngineCell::new(Arc::new(DsSoftmax::new(ds.set.clone())));
    let handle = cell.handle();
    {
        let g = handle.load();
        g.query_batch(view, 10, &mut out); // settle this engine's shapes
    }
    let n = count_allocs(|| {
        let g = handle.load();
        g.query_batch(view, 10, &mut out);
        std::hint::black_box(&out);
    });
    assert_eq!(n, 0, "warm handle-load query_batch allocated {n} times");

    // ...and stays clean across a swap: the replacement engine reuses
    // the same per-thread scratch (same shapes), so post-swap warm
    // queries are still zero-allocation
    let next: Arc<dyn SoftmaxEngine> = Arc::new(DsSoftmax::new(ds.set.clone()));
    cell.swap(next); // swap itself is off the hot path — may allocate
    let n = count_allocs(|| {
        let g = handle.load();
        g.query_batch(view, 10, &mut out);
        std::hint::black_box(&out);
    });
    assert_eq!(n, 0, "post-swap warm query_batch allocated {n} times");

    // an initialized-but-unsampled tracer adds nothing to the warm hot
    // path: the per-query sampling decision is one relaxed load plus a
    // counter bump, an untraced span guard never touches the clock or
    // the ring, and none of it allocates.  The first decision after
    // init() is the sampled one (counter starts at zero), so consume
    // it outside the counted region; with an interval of 2^30 every
    // later decision in this process is unsampled.
    trace::init(1 << 30);
    let first = trace::try_sample();
    assert_ne!(first, 0, "first post-init decision should sample");
    let n = count_allocs(|| {
        for _ in 0..8 {
            let t = trace::try_sample();
            assert_eq!(t, 0, "interval 2^30 sampled again");
            let _ctx = trace::set_ctx(t, 0);
            let _kernel = trace::span(Stage::Kernel);
            let g = handle.load();
            g.query_batch(view, 10, &mut out);
        }
        std::hint::black_box(&out);
    });
    assert_eq!(n, 0, "unsampled tracing allocated {n} times on the warm path");
    trace::init(0);

    // per-class hit accounting (the adaptation plane's input) rides the
    // same flush: the counter plane is preallocated at construction and
    // each recorded row is a borrowed slice of the arena, so a warm
    // batch with accounting enabled still allocates nothing
    let metrics = Metrics::with_topology(8, 1, 512);
    ds.query_batch(view, 10, &mut out);
    let n = count_allocs(|| {
        for r in 0..bsz {
            let (ids, _) = out.row(r);
            metrics.record_class_hits(&ids[..10.min(ids.len())]);
        }
        std::hint::black_box(&metrics);
    });
    assert_eq!(n, 0, "class-hit accounting allocated {n} times on the warm path");
    let recorded: u64 = metrics.class_hits().iter().map(|&h| h as u64).sum();
    assert_eq!(recorded, (bsz * 10) as u64, "class-hit accounting dropped hits");

    // results are still correct after the counted runs
    for r in 0..bsz {
        assert_eq!(out.len(r), out.k().min(10));
    }
}
