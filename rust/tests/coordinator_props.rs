//! Property-based invariants of the coordinator stack (routing, batching,
//! sparse-set structure) using the in-house prop harness.

use std::sync::Arc;

use ds_softmax::coordinator::engine::NativeBatchEngine;
use ds_softmax::coordinator::{Coordinator, CoordinatorConfig};
use ds_softmax::model::dssoftmax::DsSoftmax;
use ds_softmax::model::SoftmaxEngine;
use ds_softmax::prop_assert;
use ds_softmax::sparse::ExpertSet;
use ds_softmax::util::prop::{check, Gen};
use ds_softmax::util::rng::Rng;

fn random_set(g: &mut Gen) -> ExpertSet {
    let n = g.usize_in(16, 512);
    let d = [4usize, 8, 16, 32][g.rng.below(4)];
    let k = [2usize, 4, 8][g.rng.below(3)];
    let m = 1.0 + g.rng.f64() * 0.8;
    ExpertSet::synthetic(n, d, k, m, &mut g.rng)
}

/// Every synthetic ExpertSet validates and covers all classes.
#[test]
fn prop_synthetic_sets_valid() {
    check(11, 40, 64, |g| {
        let set = random_set(g);
        set.validate().map_err(|e| format!("invalid set: {e}"))?;
        let red = set.redundancy();
        prop_assert!(red.iter().all(|&r| r >= 1), "uncovered class");
        prop_assert!(
            red.iter().all(|&r| r as usize <= set.k()),
            "redundancy exceeds K"
        );
        Ok(())
    })
    .unwrap();
}

/// Routing is deterministic and in-range for arbitrary finite inputs.
#[test]
fn prop_routing_deterministic_in_range() {
    check(12, 30, 64, |g| {
        let set = random_set(g);
        let d = set.dim();
        let k = set.k();
        let ds = DsSoftmax::new(set);
        for _ in 0..10 {
            let h = g.rng.normal_vec(d, 2.0);
            let a = ds.route(&h);
            let b = ds.route(&h);
            prop_assert!(a == b, "routing not deterministic");
            prop_assert!(a.width() == 1, "default route must be single-expert");
            prop_assert!(a.expert() < k, "expert out of range");
            prop_assert!(
                a.gate_value() > 0.0 && a.gate_value() <= 1.0,
                "gate value {} out of (0,1]",
                a.gate_value()
            );
        }
        Ok(())
    })
    .unwrap();
}

/// Top-k results: sorted, deduplicated, valid ids, probs in (0,1].
#[test]
fn prop_query_wellformed() {
    check(13, 30, 64, |g| {
        let set = random_set(g);
        let n = set.n_classes;
        let d = set.dim();
        let ds = DsSoftmax::new(set);
        let k = 1 + g.rng.below(16);
        let h = g.rng.normal_vec(d, 1.0);
        let top = ds.query(&h, k);
        prop_assert!(!top.is_empty(), "empty result");
        let mut seen = std::collections::HashSet::new();
        let mut prev = f32::INFINITY;
        for &(c, p) in &top {
            prop_assert!((c as usize) < n, "class {c} out of range");
            prop_assert!(seen.insert(c), "duplicate class {c}");
            prop_assert!(p > 0.0 && p <= 1.0 + 1e-6, "prob {p}");
            prop_assert!(p <= prev + 1e-6, "not sorted");
            prev = p;
        }
        Ok(())
    })
    .unwrap();
}

/// The coordinator completes every accepted query exactly once and
/// preserves single-query semantics under concurrency.
#[test]
fn prop_coordinator_completes_all() {
    check(14, 8, 32, |g| {
        let set = random_set(g);
        let d = set.dim();
        let reference = DsSoftmax::new(set.clone());
        let engine = Arc::new(NativeBatchEngine::new(DsSoftmax::new(set)));
        let c = Coordinator::start(engine, CoordinatorConfig::default());
        let n_q = 20 + g.rng.below(60);
        let hs: Vec<Vec<f32>> = (0..n_q).map(|_| g.rng.normal_vec(d, 1.0)).collect();
        let pend: Vec<_> = hs
            .iter()
            .map(|h| c.submit(h.clone(), 4))
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| format!("submit failed: {e}"))?;
        for (h, p) in hs.iter().zip(pend) {
            let got = p.wait().map_err(|e| format!("query failed: {e}"))?;
            let want = reference.query(h, 4);
            prop_assert!(got == want, "coordinator diverged from reference");
        }
        Ok(())
    })
    .unwrap();
}

/// Speedup formula is monotone: pruning an expert (smaller |v_k|) never
/// decreases the theoretical speedup.
#[test]
fn prop_speedup_monotone_in_expert_size() {
    check(15, 30, 64, |g| {
        let mut set = random_set(g);
        let k = set.k();
        let uniform = vec![1.0 / k as f64; k];
        let before = set.speedup(&uniform);
        // shrink expert 0 by dropping its last valid row
        let e = &mut set.experts[0];
        if e.valid > 1 {
            let last = e.valid - 1;
            let class = e.class_ids[last];
            e.class_ids[last] = -1;
            for x in e.weights.row_mut(last) {
                *x = 0.0;
            }
            e.valid -= 1;
            let after = set.speedup(&uniform);
            prop_assert!(
                after >= before,
                "speedup decreased after shrink: {before} -> {after} (dropped class {class})"
            );
        }
        Ok(())
    })
    .unwrap();
}

/// Shutdown never strands a `Pending`: every query admitted before the
/// stop resolves (the dispatcher drains its per-expert queues and the
/// worker pool joins before shutdown returns), an impatient caller
/// whose `wait_timeout` expired can still collect the result
/// afterwards — no in-flight slot lives forever — and submissions
/// after the stop fail fast with `Shutdown` instead of hanging or
/// masquerading as backpressure.
#[test]
fn shutdown_drains_inflight_pendings() {
    use ds_softmax::coordinator::batcher::BatchPolicy;
    use ds_softmax::coordinator::QueryError;
    use ds_softmax::query::{MatrixView, Route, TopKBuf};
    use std::time::Duration;

    /// Slow single-expert engine: each flush stalls long enough that a
    /// burst of queries is still in flight when shutdown begins.
    struct SlowEngine;
    impl SoftmaxEngine for SlowEngine {
        fn query_batch(&self, hs: MatrixView<'_>, k: usize, out: &mut TopKBuf) {
            out.reset(hs.rows, k);
            for r in 0..hs.rows {
                out.push(r, 0, 1.0);
            }
        }
        fn route_batch(&self, hs: MatrixView<'_>, out: &mut [Route]) {
            assert_eq!(hs.rows, out.len());
            for r in out.iter_mut() {
                *r = Route::single(0, 1.0);
            }
        }
        fn run_expert_batch(
            &self,
            _expert: usize,
            hs: MatrixView<'_>,
            gates: &[f32],
            k: usize,
            out: &mut TopKBuf,
        ) -> anyhow::Result<()> {
            anyhow::ensure!(hs.rows == gates.len());
            std::thread::sleep(Duration::from_millis(3));
            self.query_batch(hs, k, out);
            Ok(())
        }
        fn flops_per_query(&self) -> u64 {
            0
        }
        fn n_classes(&self) -> usize {
            1
        }
        fn dim(&self) -> usize {
            4
        }
        fn name(&self) -> &'static str {
            "slow"
        }
    }

    let cfg = CoordinatorConfig {
        workers: 1,
        policy: BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(50) },
        ..Default::default()
    };
    let c = Coordinator::start(Arc::new(SlowEngine), cfg);
    let pend: Vec<_> = (0..40)
        .map(|_| c.submit(vec![0.5; 4], 1).expect("submit"))
        .collect();
    // impatient callers: their timeout expires while flushes are still
    // grinding through the single slow worker — the slot must survive
    let mut timed_out = 0;
    for p in pend.iter().take(10) {
        if p.wait_timeout(Duration::from_micros(200)).is_none() {
            timed_out += 1;
        }
    }
    c.shutdown();
    // after shutdown every pending resolves — admitted queries drain
    // with real results; nothing hangs, nothing resolves twice
    let mut ok = 0;
    for p in pend {
        match p.wait() {
            Ok(rows) => {
                assert_eq!(rows, vec![(0, 1.0)]);
                ok += 1;
            }
            Err(e) => panic!("admitted query lost at shutdown: {e}"),
        }
    }
    assert_eq!(ok, 40);
    assert!(timed_out > 0, "timeouts never exercised (machine too fast?)");
    assert_eq!(
        c.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        40
    );
    // post-shutdown submissions shed with Shutdown, not backpressure
    match c.submit(vec![0.5; 4], 1) {
        Err(QueryError::Shutdown) => {}
        other => panic!("want Shutdown, got {:?}", other.map(|_| ())),
    }
}

/// Utilization measured by the metrics plane matches the empirical
/// routing distribution exactly.
#[test]
fn metrics_utilization_consistent() {
    let mut rng = Rng::new(77);
    let set = ExpertSet::synthetic(128, 8, 4, 1.2, &mut rng);
    let reference = DsSoftmax::new(set.clone());
    let engine = Arc::new(NativeBatchEngine::new(DsSoftmax::new(set)));
    let c = Coordinator::start(engine, CoordinatorConfig::default());
    let mut counts = vec![0u64; 4];
    for _ in 0..300 {
        let h = rng.normal_vec(8, 1.0);
        counts[reference.route(&h).expert()] += 1;
        let _ = c.query(h, 1);
    }
    let u = c.metrics.utilization();
    for (e, &cnt) in counts.iter().enumerate() {
        let want = cnt as f64 / 300.0;
        assert!((u[e] - want).abs() < 1e-9, "expert {e}: {} vs {want}", u[e]);
    }
}
