//! Property-testing substrate (no `proptest`/`quickcheck` offline).
//!
//! A small deterministic harness: generators draw from a seeded [`Rng`],
//! `check` runs N cases and on failure re-runs a bounded shrink loop by
//! retrying with "smaller" draws (size parameter decay).  It covers what
//! the coordinator/sparse invariant tests need without the full
//! shrinking machinery of proptest.

use crate::util::rng::Rng;

/// A generation context: seeded randomness + a size hint that the shrink
/// loop decays.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self { rng: Rng::new(seed), size }
    }

    /// Vec length in [1, size].
    pub fn len(&mut self) -> usize {
        1 + self.rng.below(self.size.max(1))
    }

    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        self.rng.normal_vec(n, scale)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo).max(1))
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct PropResult {
    pub cases: usize,
    pub failure: Option<String>,
}

impl PropResult {
    pub fn unwrap(self) {
        if let Some(f) = self.failure {
            panic!("property failed after {} cases: {f}", self.cases);
        }
    }
}

/// Run `prop` over `cases` generated inputs.  On the first failure,
/// retry with decreasing size to report the smallest failing size seen.
pub fn check<F>(seed: u64, cases: usize, max_size: usize, prop: F) -> PropResult
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E37_79B9).wrapping_add(case as u64);
        let mut g = Gen::new(case_seed, max_size);
        if let Err(msg) = prop(&mut g) {
            // shrink: re-run with smaller sizes, same seed family
            let mut best = (max_size, msg);
            let mut size = max_size / 2;
            while size >= 1 {
                let mut g = Gen::new(case_seed, size);
                if let Err(m) = prop(&mut g) {
                    best = (size, m);
                }
                size /= 2;
            }
            return PropResult {
                cases: case + 1,
                failure: Some(format!(
                    "seed={case_seed} size={}: {}",
                    best.0, best.1
                )),
            };
        }
    }
    PropResult { cases, failure: None }
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let r = check(1, 50, 100, |g| {
            let n = g.len();
            prop_assert!(n >= 1 && n <= 100, "len out of range: {n}");
            Ok(())
        });
        assert_eq!(r.cases, 50);
        assert!(r.failure.is_none());
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let r = check(2, 100, 64, |g| {
            let n = g.len();
            prop_assert!(n < 10, "too big: {n}");
            Ok(())
        });
        let f = r.failure.expect("must fail");
        assert!(f.contains("too big"));
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let sizes = std::sync::Mutex::new(Vec::new());
            check(3, 10, 32, |g| {
                sizes.lock().unwrap().push(g.len());
                Ok(())
            });
            sizes.into_inner().unwrap()
        };
        assert_eq!(run(), run());
    }
}
